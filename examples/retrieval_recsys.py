"""Recsys retrieval example: the paper's top-k machinery reused for the
xdeepfm `retrieval_cand` cell — score one query against a large candidate
table and take the exact top-k with the streaming Pallas kernel.

    PYTHONPATH=src python examples/retrieval_recsys.py [--candidates 100000]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.models import recsys as rc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidates", type=int, default=100_000)
    ap.add_argument("--k", type=int, default=20)
    args = ap.parse_args()

    cfg = rc.XDeepFMConfig(
        name="retrieval-demo", n_sparse=8, embed_dim=16,
        table_rows=args.candidates, cin_layers=(32, 32), mlp_layers=(64,),
    )
    params = rc.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, cfg.table_rows, (1, cfg.n_sparse, cfg.bag_size)).astype(np.int32)
    batch = {"sparse_ids": jnp.asarray(ids), "n_candidates": args.candidates}

    t0 = time.perf_counter()
    oid, od = rc.retrieval_score(params, batch, cfg, k=args.k, use_pallas=False)
    jax.block_until_ready(od)
    t_xla = time.perf_counter() - t0
    print(f"top-{args.k} of {args.candidates:,} candidates in {t_xla * 1e3:.1f}ms (XLA)")
    print("ids   :", np.asarray(oid)[0, :8])
    print("scores:", np.round(np.asarray(od)[0, :8], 3))

    # kernel path (interpret mode on CPU; compiled VMEM pipeline on TPU)
    oid2, od2 = rc.retrieval_score(params, batch, cfg, k=args.k, use_pallas=True)
    match = bool((np.asarray(oid) == np.asarray(oid2)).all())
    print(f"pallas kernel agrees with oracle: {match}")


if __name__ == "__main__":
    main()
