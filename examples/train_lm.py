"""End-to-end LM training driver: train a transformer for a few hundred steps
on a learnable Markov stream and watch the loss fall toward the chain entropy.

    PYTHONPATH=src python examples/train_lm.py                  # ~15M params, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --full --steps 300  # ~100M params

Uses the same step builders / optimizer / checkpointing the production
launcher uses; on a TPU mesh the identical script runs sharded (the step is
built through make_lm_train with the mesh's sharding rules).
"""
import argparse
import math
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import MarkovLMStream
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tr
from repro.optim import adamw
from repro.train import steps as steps_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--full", action="store_true", help="~100M-param config")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    if args.full:
        cfg = tr.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=8192, param_dtype=jnp.float32,
            q_chunk=64, kv_chunk=64,
        )
    else:
        cfg = tr.TransformerConfig(
            name="lm-15m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
            d_head=32, d_ff=512, vocab=512, param_dtype=jnp.float32,
            q_chunk=32, kv_chunk=32,
        )
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    branching = 4
    stream = MarkovLMStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                            branching=branching)
    print(f"target loss (chain entropy) = ln({branching}) = {math.log(branching):.3f}")

    mesh = make_host_mesh(data=len(jax.devices()))
    rules = make_rules(mesh)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                                weight_decay=0.01)
    fn, *_ = steps_mod.make_lm_train(cfg, rules, opt_cfg)
    step_fn = jax.jit(fn, donate_argnums=(0, 1))

    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    t0 = time.time()
    first = None
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, stream.batch_at(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  ({time.time() - t0:.0f}s)")
    print(f"\nloss: {first:.3f} -> {loss:.3f} "
          f"(entropy floor {math.log(branching):.3f})")
    assert loss < first - 0.5, "training should clearly reduce loss"


if __name__ == "__main__":
    main()
