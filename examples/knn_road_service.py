"""Location-based-service scenario (paper Figure 1 / Exp-9): a running kNN
service over a road network with mixed query + object-update traffic.

    PYTHONPATH=src python examples/knn_road_service.py [--grid 40] [--k 20]

Simulates a Yelp/Uber-style workload: 95% kNN queries ("nearest coffee"),
5% object updates (stores opening/closing), under the two arrival models the
paper benchmarks (BUA+QF and RUA+FCFS), printing throughput for each.
"""
import argparse
import time

import numpy as np

from repro.core.bngraph import build_bngraph
from repro.core.reference import knn_index_cons_plus
from repro.core.updates import delete_object, insert_object
from repro.graph.generators import pick_objects, road_network


def run_workload(bn, idx, objects, n_ops: int, update_frac: float, k: int,
                 mode: str, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    mset = set(objects.tolist())
    ops_done = 0
    queries = rng.integers(0, bn.n, size=n_ops)
    is_update = rng.random(n_ops) < update_frac
    t0 = time.perf_counter()
    if mode == "bua_qf":  # queries first, then the update batch
        order = np.argsort(is_update, kind="stable")
    else:  # rua_fcfs: arrival order
        order = np.arange(n_ops)
    for i in order:
        if is_update[i]:
            v = int(queries[i])
            if v in mset and len(mset) > k + 1:
                delete_object(bn, idx, v)
                mset.discard(v)
            elif v not in mset:
                insert_object(bn, idx, v)
                mset.add(v)
        else:
            idx.query(int(queries[i]))
        ops_done += 1
    return ops_done / (time.perf_counter() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=40)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--mu", type=float, default=0.02)
    ap.add_argument("--ops", type=int, default=3000)
    args = ap.parse_args()

    g = road_network(args.grid, args.grid, seed=0)
    objects = pick_objects(g.n, args.mu, seed=0)
    print(f"network: n={g.n} m={g.m}; |M|={len(objects)}; k={args.k}")
    t0 = time.perf_counter()
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, args.k)
    print(f"index built in {time.perf_counter() - t0:.2f}s "
          f"({idx.size_bytes() / 1024:.0f} KiB)")

    for mode in ("bua_qf", "rua_fcfs"):
        thr = run_workload(bn, idx.copy(), objects, args.ops, 0.05, args.k, mode)
        print(f"{mode:10s}: {thr:,.0f} ops/s (95% queries / 5% updates)")


if __name__ == "__main__":
    main()
