"""Location-based-service scenario (paper Figure 1 / Exp-9): a running kNN
service over a road network with mixed query + object-update traffic.

    PYTHONPATH=src python examples/knn_road_service.py [--grid 40] [--k 20]

Simulates a Yelp/Uber-style workload: 95% kNN queries ("nearest coffee"),
5% object updates (stores opening/closing). Two serving paths over the SAME
traffic:

  scalar host loop — one ``KNNIndex.query`` / ``insert_object`` /
      ``delete_object`` Python call per op (the paper's per-request model,
      kept as the baseline);
  batched QueryEngine — queries served in ``query_batch`` tiles, updates
      staged into the engine queue and flushed once per tile (the BUA
      arrival model), everything device-resident via ``repro.knn``.

Then switches the update traffic to the *moving-fleet* workload (the Uber
half of the story: the objects are vehicles, and the dominant update is the
same vehicle moving one street over): a ``knn.FleetSim`` drives the fleet
along shortest-path trips, every tick's (src, dst) moves are staged via
``stage_move`` and flushed as one fused device batch between query tiles.

Prints the throughputs and speedups; the engine paths are also what
``repro.launch.serve --arch knn-index [--workload fleet]`` runs as a service.
"""
import argparse
import time

import jax
import numpy as np

from repro import knn


def run_scalar_loop(bn, idx, objects, n_ops: int, update_frac: float, k: int,
                    mode: str, seed: int = 0) -> float:
    """Baseline: per-op Python dispatch (one row scan / heap loop per call)."""
    rng = np.random.default_rng(seed)
    mset = set(objects.tolist())
    ops_done = 0
    queries = rng.integers(0, bn.n, size=n_ops)
    is_update = rng.random(n_ops) < update_frac
    t0 = time.perf_counter()
    if mode == "bua_qf":  # queries first, then the update batch
        order = np.argsort(is_update, kind="stable")
    else:  # rua_fcfs: arrival order
        order = np.arange(n_ops)
    for i in order:
        if is_update[i]:
            v = int(queries[i])
            if v in mset and len(mset) > k + 1:
                knn.delete_object(bn, idx, v)
                mset.discard(v)
            elif v not in mset:
                knn.insert_object(bn, idx, v)
                mset.add(v)
        else:
            idx.query(int(queries[i]))
        ops_done += 1
    return ops_done / (time.perf_counter() - t0)


def run_engine_batched(engine, n_ops: int, update_frac: float,
                       batch: int, seed: int = 0) -> dict:
    """Engine path: query tiles + staged updates flushed per tile (BUA+QF)."""
    rng = np.random.default_rng(seed)
    mset = set(engine.objects.tolist())
    n_upd = int(round(batch * update_frac))
    n_q = batch - n_upd

    def one_tile():
        us = rng.integers(0, engine.n, size=n_q)
        jax.block_until_ready(engine.query_batch(us)[0])
        if knn.stage_random_updates(engine, mset, rng, n_upd):
            engine.flush_updates()

    one_tile()  # compile the gather + the flush repair programs, untimed
    ops_done = queries = updates = 0
    t_q = t_u = 0.0
    while ops_done < n_ops:
        t0 = time.perf_counter()
        ids, _ = engine.query_batch(rng.integers(0, engine.n, size=n_q))
        jax.block_until_ready(ids)
        t_q += time.perf_counter() - t0
        queries += n_q
        t0 = time.perf_counter()
        staged = knn.stage_random_updates(engine, mset, rng, n_upd)
        if staged:
            engine.flush_updates()
        t_u += time.perf_counter() - t0
        updates += staged
        ops_done += n_q + staged
    return {
        "ops_per_s": ops_done / max(t_q + t_u, 1e-9),
        "queries_per_s": queries / max(t_q, 1e-9),
        "updates_per_s": updates / max(t_u, 1e-9) if updates else 0.0,
    }


def run_fleet(g, bn, k: int, fleet_size: int, ticks: int, batch: int,
              seed: int = 0) -> dict:
    """Moving-fleet path: per tick, stage the tick's moves + serve a tile."""
    from repro.workloads import drive_fleet_ticks

    sim = knn.FleetSim(g, fleet_size=fleet_size, seed=seed)
    engine = knn.build_engine(bn, sim.positions, k)
    rng = np.random.default_rng(seed)
    jax.block_until_ready(engine.query_batch(rng.integers(0, g.n, size=batch))[0])
    r = drive_fleet_ticks(
        engine, (sim.tick() for _ in range(ticks)), batch=batch, rng=rng
    )
    return {
        "ticks_per_s": ticks / r["wall_s"],
        "moves_per_tick": sim.moves_total / ticks,
        "query_p50_us": float(np.percentile(r["lat"], 50)) * 1e6,
        "query_p99_us": float(np.percentile(r["lat"], 99)) * 1e6,
        "engine": engine,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=40)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--mu", type=float, default=0.02)
    ap.add_argument("--ops", type=int, default=3000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--update-frac", type=float, default=0.05)
    ap.add_argument("--fleet-size", type=int, default=128)
    ap.add_argument("--ticks", type=int, default=30)
    args = ap.parse_args()

    g = knn.road_network(args.grid, args.grid, seed=0)
    objects = knn.pick_objects(g.n, args.mu, seed=0)
    print(f"network: n={g.n} m={g.m}; |M|={len(objects)}; k={args.k}")
    t0 = time.perf_counter()
    bn = knn.build_bngraph(g)
    engine = knn.QueryEngine.build(bn, objects, args.k)
    idx = engine.to_index()
    print(f"index built in {time.perf_counter() - t0:.2f}s "
          f"({idx.size_bytes(dist_bytes=4) / 1024:.0f} KiB on device)")

    base = {}
    for mode in ("bua_qf", "rua_fcfs"):
        thr = run_scalar_loop(bn, idx.copy(), objects, args.ops, args.update_frac,
                              args.k, mode)
        base[mode] = thr
        print(f"scalar {mode:10s}: {thr:,.0f} ops/s "
              f"({1 - args.update_frac:.0%} queries / {args.update_frac:.0%} updates)")

    r = run_engine_batched(engine, args.ops, args.update_frac, args.batch)
    print(f"engine bua_qf (batch={args.batch}): {r['ops_per_s']:,.0f} ops/s "
          f"(x{r['ops_per_s'] / base['bua_qf']:.1f} vs scalar loop); "
          f"queries alone {r['queries_per_s']:,.0f}/s, "
          f"updates alone {r['updates_per_s']:,.0f}/s")
    print("engine stats:", engine.stats())

    print(f"\nmoving fleet: {args.fleet_size} vehicles on shortest-path trips, "
          f"{args.ticks} serving ticks (one fused stage_move flush per tick)")
    f = run_fleet(g, bn, args.k, args.fleet_size, args.ticks, args.batch)
    es = f["engine"].stats()
    print(f"fleet: {f['ticks_per_s']:.1f} ticks/s at "
          f"{f['moves_per_tick']:.0f} moves/tick; query p50 "
          f"{f['query_p50_us']:.0f} us / p99 {f['query_p99_us']:.0f} us "
          f"while flushing")
    print(f"fleet engine: {es['moves_applied']} moves applied, "
          f"{es['coalesced']} staged ops coalesced away, "
          f"{es['rows_repaired']} rows repaired")


if __name__ == "__main__":
    main()
