"""Quickstart: the paper end to end in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic road network, constructs the KNN-Index with the
bidirectional algorithm (host reference AND the TPU-style level-synchronous
sweeps), answers queries progressively, maintains the index through object
insertions/deletions, serves batched traffic through the ``repro.knn``
QueryEngine facade, runs the moving-fleet workload (vehicles on shortest-path
trips whose per-tick moves are staged with ``stage_move`` and flushed as one
fused device batch between query batches), and finishes with the durability
surface: epoch-versioned snapshot-isolated flushes, pinned time-travel reads,
and write-ahead-journal crash recovery.
"""
import os
import tempfile

import numpy as np

from repro import knn
from repro.core.bngraph import build_bngraph
from repro.core.construct_jax import build_knn_index_jax, prepare_sweep
from repro.core.index import indices_equivalent
from repro.core.reference import knn_index_cons_plus
from repro.core.updates import delete_object, insert_object
from repro.graph.generators import pick_objects, road_network


def main():
    k = 10
    print("== 1. road network ==")
    g = road_network(40, 40, seed=0)
    objects = pick_objects(g.n, mu=0.02, seed=0)
    print(f"n={g.n} m={g.m} |M|={len(objects)} k={k}")

    print("\n== 2. BN-Graph (Algorithm 1) ==")
    bn = build_bngraph(g)
    plan = prepare_sweep(bn, "up")
    print(f"rho={bn.rho} tau={bn.tau} levels={plan.num_levels} "
          f"chunks={plan.num_chunks} shape-buckets={len(plan.buckets)} "
          f"pad-occupancy={plan.occupancy:.2f}")

    print("\n== 3. construction: Algorithm 3 (host) vs level-sync sweeps (device) ==")
    idx_host = knn_index_cons_plus(bn, objects, k)
    idx_dev = build_knn_index_jax(bn, objects, k, use_pallas=False)
    print(f"identical results: {indices_equivalent(idx_host, idx_dev)}")
    print(f"index size: {idx_dev.size_bytes(dist_bytes=4) / 1024:.1f} KiB "
          f"(= n*k*8 bytes on device, Theorem 4.5)")

    print("\n== 4. queries (O(k), progressive) ==")
    u = 777
    print(f"kNN({u}) = {idx_dev.query(u, 5)}")
    print("progressive:", end=" ")
    for i, (v, d) in enumerate(idx_dev.query_progressive(u, 3)):
        print(f"#{i + 1}:({v},{d:.0f})", end=" ")
    print()

    print("\n== 5. maintenance (Algorithms 4/5) ==")
    new_obj = int(np.setdiff1d(np.arange(g.n), objects)[0])
    delta = insert_object(bn, idx_dev, new_obj)
    print(f"insert {new_obj}: {delta} rows touched; kNN({u}) = {idx_dev.query(u, 5)}")
    delta = delete_object(bn, idx_dev, new_obj)
    print(f"delete {new_obj}: {delta} rows touched")
    print(f"back to original: {indices_equivalent(idx_host, idx_dev)}")

    print("\n== 6. serving (repro.knn facade: batched device-resident engine) ==")
    engine = knn.build_engine(bn, objects, k)
    us = np.arange(0, g.n, 7, dtype=np.int32)
    ids, dists = engine.query_batch(us)              # one gather, whole batch
    print(f"query_batch({len(us)} queries): ids {ids.shape}, "
          f"first row {np.asarray(ids[0, :3]).tolist()}")
    for prefix_ids, _ in engine.query_progressive_batch(us[:4], 3):
        pass                                          # first-i prefixes, one gather
    print(f"progressive prefixes up to i={prefix_ids.shape[1]} for "
          f"{prefix_ids.shape[0]} queries")
    engine.stage_insert(new_obj)                      # queued, not yet visible
    print(f"staged queue depth: {engine.queue_depth}; "
          f"flush: {engine.flush_updates()}")
    path = os.path.join(tempfile.mkdtemp(), "index.npz")
    engine.save(path)                                 # same artifact knn_build --out writes
    engine2 = knn.load_engine(path, bn=bn)
    print(f"save/load round-trip equivalent: "
          f"{indices_equivalent(engine.to_index(), engine2.to_index())}")
    print(f"engine stats: {engine.stats()}")

    print("\n== 7. moving fleet (build -> simulate -> query while moving) ==")
    sim = knn.FleetSim(g, fleet_size=64, seed=0)      # vehicles on sp trips
    fleet_engine = knn.build_engine(bn, sim.positions, k)
    for _ in range(3):                                # one serving tick each
        moves = sim.tick()                            # vehicles advance a street
        for src, dst in moves:
            fleet_engine.stage_move(src, dst)         # staged, not yet visible
        fleet_engine.query_batch(us[:64])             # queries see flushed state
        stats = fleet_engine.flush_updates()          # one fused move batch
    print(f"tick: {len(moves)} moves staged -> flush {stats}")
    print(f"fleet sim: {sim.stats()}")

    print("\n== 8. sharded serving (vertex-partitioned multi-device engine) ==")
    # The flat (n+1, k) table is embarrassingly partitionable by vertex:
    # shard s owns the contiguous range [s*R, (s+1)*R), R = ceil(n/S), one
    # local block per device on a 1-D mesh. Queries route to their owner
    # shard (one device roundtrip per batch); flushes run per shard with
    # only frontier vertex ids crossing shard boundaries between repair
    # rounds. On CPU, expose more devices BEFORE the process starts:
    #     XLA_FLAGS=--xla_force_host_platform_device_count=8
    # (serve.py --shards N and knn_build artifacts work the same way; this
    # demo uses however many devices the current process can see.)
    import jax

    shards = min(2, len(jax.devices()))
    sharded = knn.build_sharded_engine(bn, objects, k, shards=shards)
    s_ids, _ = sharded.query_batch(us)                # routed gather
    print(f"shards={shards} ({len(jax.devices())} devices visible); "
          f"bit-identical to scalar engine: "
          f"{bool(np.array_equal(np.asarray(s_ids), np.asarray(ids)))}")
    st = sharded.stats()
    # Padding cost of equal shard rows: S*(R+1) - n wasted rows. Tiny here,
    # but worth watching when n is small relative to the shard count or when
    # a hot shard forces replication — see stats()['row_padding_overhead'].
    print(f"shard rows={st['shard_rows']} padded rows={st['padded_rows']} "
          f"(overhead {st['row_padding_overhead']:.2%})")
    sharded.save(path)                                # artifact is shard-free
    resharded = knn.load_engine(path, bn=bn, shards=1)   # reshard-on-load
    print(f"reshard-on-load equivalent: "
          f"{indices_equivalent(sharded.to_index(), resharded.to_index())}")

    print("\n== 9. batched checkIns frontier (device-resident insert flushes) ==")
    # A flush with many staged inserts runs Algorithm 4's checkIns frontier
    # for the WHOLE batch as one multi-source pruned-relaxation program on
    # device: round r relaxes the BNS edges of every vertex whose tentative
    # distance changed in round r-1, pruned by the live k-th-distance column
    # (which never leaves the device — only changed-row masks and the final
    # affected rows' distances come back). The pre-batching pipeline — one
    # host heap search per object fed by an (n,) kth readback — survives as
    # engine.frontier = "host"; both produce identical tables, so the choice
    # is purely a throughput knob (exp14: device >= 1.3x at batch 512).
    batch_engine = knn.build_engine(bn, objects, k)
    absent = np.setdiff1d(np.arange(g.n), objects)[:64]
    for v in absent:
        batch_engine.stage_insert(int(v))
    flush = batch_engine.flush_updates()
    print(f"staged {len(absent)} inserts -> one flush: "
          f"{flush['rows_merged']} rows merged in "
          f"{flush['frontier_rounds']} frontier rounds")
    st = batch_engine.stats()
    # per-phase flush timings (cumulative): where a flush actually spends
    # its time — frontier search vs fused purge+merge vs delete repair
    print("per-phase flush seconds: "
          f"frontier={st['t_frontier_s']:.4f} "
          f"purge_merge={st['t_purge_merge_s']:.4f} "
          f"repair={st['t_repair_s']:.4f}")

    print("\n== 10. durability & epochs (crash-safe serving) ==")
    # Every flush publishes a new immutable epoch: queries resolve their
    # dispatch-time snapshot, so a slow reader never observes a half-built
    # table, and keep_epochs retains older epochs for pinned reads
    # (query_batch(..., epoch=e)). Attaching a write-ahead journal makes
    # staged updates durable BEFORE they are acknowledged: a process killed
    # mid-flush replays the journal on load and recovers byte-identical
    # tables (tests/chaos drives a kill at every pipeline checkpoint).
    wal = os.path.join(tempfile.mkdtemp(), "updates.wal")
    dur = knn.load_engine(path, bn=bn, journal=wal)   # journal from here on
    dur.keep_epochs = 3
    pinned = dur.epoch                                # epoch to time-travel to
    before = np.asarray(dur.query_batch(us)[0])
    dur.stage_insert(int(np.setdiff1d(np.arange(g.n), dur.objects)[0]))
    dur.flush_updates()                               # journal commit + swap
    print(f"epoch {pinned} -> {dur.epoch}; retained={dur.retained_epochs()}; "
          f"origin={dur.epoch_stats()['origin']}")
    old = np.asarray(dur.query_batch(us, epoch=pinned)[0])
    print(f"pinned read of epoch {pinned} unchanged: "
          f"{bool(np.array_equal(old, before))}")
    # crash recovery: a NEW process loads artifact + journal -> same tables
    rec = knn.load_engine(path, bn=bn, journal=wal)
    print(f"journal replay recovers epoch {rec.epoch}: bit-identical "
          f"{bool(np.array_equal(np.asarray(rec.to_index().ids), np.asarray(dur.to_index().ids)))}")
    try:                                              # corruption is typed
        knn.UpdateJournal(path)                       # npz is not a journal
    except knn.JournalError as e:
        print(f"typed corruption error: JournalError: {e}")
    print(f"epoch stats: {dur.stats()['epochs_retained']} retained, "
          f"{dur.stats()['epoch_table_bytes']} table bytes")

    print("\n== 11. replicated hot shards (shard -> replica-set fan-out) ==")
    # Skewed urban traffic pins one vertex range: with equal shard ranges,
    # one device saturates while the rest idle. set_replication({shard: R})
    # copies the hot shard's epoch buffers onto R extra devices at publish
    # time — same atomic epoch step, so pinned reads stay bit-identical on
    # every replica — and query batches fan out across the replica set
    # (round_robin or least_outstanding). Flushes still go to the primary
    # only: replicas are a serving concern, not a write path. Worth it when
    # the hot shard's share of traffic dwarfs the padding a narrower
    # per-replica batch pays (exp16: zipf-skewed mix, >= 1.5x q/s at
    # 4 shards x 3 replicas); serve.py --replicate SHARD:R or auto:R picks
    # the hottest shard from a sliding query histogram.
    import jax

    free = len(jax.devices()) - sharded.num_shards
    if free > 0:
        hot = 0
        sharded.set_replication({hot: min(3, free)}, policy="round_robin")
        r_ids, _ = sharded.query_batch(us)
        rst = sharded.stats()
        print(f"plan {rst['replication']} -> {rst['replica_slots']} slots "
              f"({rst['replica_policy']}); bit-identical through replicas: "
              f"{bool(np.array_equal(np.asarray(r_ids), np.asarray(ids)))}")
        print(f"replica traffic: {rst['replica_queries']} queries in "
              f"{rst['replica_batches']} batches, "
              f"errors={rst['replica_errors']}")
        sharded.set_replication(None)                 # drop back to primaries
    else:
        print(f"no devices free beyond the {sharded.num_shards} shard "
              f"primaries - start with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8 to see "
              f"the fan-out")

    print("\n== 12. uneven shard ranges (traffic-aware repartition) ==")
    # The other answer to skew: instead of paying replica copies for a hot
    # range, move the range *boundaries* so every shard owns an equal share
    # of the observed traffic. knn.PartitionPlan is the one layout surface —
    # shards, ranges (explicit boundary vector or "auto"), replication and
    # routing policy in a single value accepted by build_sharded_engine,
    # load_engine and serve.py --partition; the old shards=/replication=
    # kwargs survive as deprecation shims. propose_starts turns a per-vertex
    # query histogram into balanced boundaries, and repartition() stages
    # them for the next flush: the tables are re-laid on device and
    # published with the layout in ONE atomic epoch step, so pinned reads
    # on older epochs keep serving under their OLD boundaries, and a flush
    # killed mid-repartition rolls back whole (never a torn layout, the
    # repartition stays staged for the retry — tests/core/test_repartition
    # drives every checkpoint). Prefer ranges over replicas when the skew is
    # broad (a hot *region*, zipf-ish traffic: exp17 holds >= 1.3x q/s over
    # equal-width with ZERO extra devices); prefer replicas when one range
    # is hot beyond what any boundary move can dilute. serve.py
    # --partition shards=4,ranges=auto does this live from the query stream.
    if sharded.num_shards > 1:
        hist = np.bincount(np.repeat(us, 3), minlength=g.n).astype(np.float64)
        starts = knn.propose_starts(hist, sharded.num_shards)
        pinned = sharded.epoch
        sharded.repartition(starts)                   # stage + flush in one
        u_ids, _ = sharded.query_batch(us)
        pst = sharded.stats()
        print(f"boundaries {pst['shard_starts']} (uneven={pst['uneven_ranges']}, "
              f"repartitions={pst['repartitions']})")
        old_ids = np.asarray(sharded.query_batch(us, epoch=pinned)[0])
        print(f"bit-identical after repartition: "
              f"{bool(np.array_equal(np.asarray(u_ids), np.asarray(ids)))}; "
              f"pinned epoch {pinned} still serves the old layout: "
              f"{bool(np.array_equal(old_ids, np.asarray(ids)))}")
        plan = knn.PartitionPlan.parse(f"shards={sharded.num_shards}")
        print(f"plan surface: {sharded.partition_plan().describe()} "
              f"(parse('shards=N') == legacy shards=N: "
              f"{plan.shards == sharded.num_shards})")
    else:
        print("single shard - boundaries have nowhere to move")

    print("\n== 13. collective halo exchange (device-resident flush repair) ==")
    # Multi-shard flushes need a halo: when a repair round changes rows on
    # one shard, the BNS neighborhoods of those rows — wherever they live —
    # become the next round's candidates, and the frontier's gated rows
    # cross boundaries the same way. halo="host" (the original seam) routes
    # those rows through host readbacks + numpy set algebra; the default
    # halo="collective" keeps every row device-resident: receiver sets
    # expand as a psum'd presence mask over the sharded BNS CSR, and the
    # rows themselves move shard-to-shard as capacity-padded
    # all_gather multicasts — only the integer routing plans go up and one
    # changed-mask comes back per round. Both modes are bit-identical to
    # the scalar oracle (tests/core/test_halo.py pins this, and the traffic
    # guard proves collective flushes never touch the routed host
    # fetchers); exp18 holds collective >= 1.2x host flush throughput at
    # 8 shards, batch 512. engine.halo_capacity bounds the padded
    # per-shard-pair slot count (default 4096, rounded up to powers of
    # two): a repair round too wide to fit falls back to the routed host
    # path for that round only — counted in stats()['halo_fallbacks'],
    # never visible in results. Raise it if fallbacks show up under heavy
    # churn; lower it to cap exchange buffer memory on wide fan-outs.
    if sharded.num_shards > 1:
        sharded.stage_insert(int(np.setdiff1d(np.arange(g.n), sharded.objects)[0]))
        sharded.flush_updates()
        hst = sharded.stats()
        print(f"halo={hst['halo']}: {hst['halo_rounds_collective']} collective "
              f"rounds, {hst['halo_fallbacks']} overflow fallbacks")
    else:
        print("single shard - nothing crosses a boundary")
    # Cold boots recompile every serving program; a persistent compilation
    # cache makes the SECOND process boot warm. serve.py --compile-cache DIR
    # (or the REPRO_COMPILE_CACHE env var) configures it before anything
    # compiles; programmatically it is one call, safe to leave on:
    #     from repro.analysis import sanitize
    #     sanitize.enable_compile_cache("~/.cache/repro-xla")
    # sanitize.count_compiles() splits real compiles from cache hits
    # (counter.uncached), which is how the cold-boot budget test holds a
    # warm-cache boot to the *warm* serving budgets.


if __name__ == "__main__":
    main()
