"""Sharded checkpointing with atomic commit and reshard-on-restore.

Layout:  <dir>/step_<N>/
           manifest.json            tree structure + shapes/dtypes + step
           shard_<host>.npz         this host's param/opt shards

Writes go to step_<N>.tmp and are renamed atomically after fsync, so a crash
mid-save never corrupts the latest checkpoint (restart scans for the newest
complete manifest). Restore takes a target sharding tree and re-places arrays
under it, which is also the elastic-rescale path: the same checkpoint restores
onto a smaller/larger surviving mesh (tests cover 8 -> 4 devices).
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SEP = "\x1f"  # key-path separator inside npz archives

try:  # numpy cannot serialise bfloat16 natively; store as uint16 bit pattern
    import ml_dtypes

    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _BF16 = None


def _savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    if _BF16 is not None and a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr, dt = _savable(np.asarray(leaf))
        flat[key] = arr
        dtypes[key] = dt
    return flat, dtypes


def save(ckpt_dir: str | Path, step: int, tree: Any, *, host_id: int = 0) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, dtypes = _flatten(tree)
    np.savez(tmp / f"shard_{host_id}.npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]} for k, v in flat.items()},
        "hosts": 1,
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `like`; optionally re-place under
    `shardings` (a matching tree of jax.sharding.Sharding) — the elastic path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = dict(np.load(d / "shard_0.npz"))
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(paths)
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        if manifest["leaves"][key]["dtype"] == "bfloat16" and _BF16 is not None:
            arr = arr.view(_BF16)
        if hasattr(leaf, "dtype") and str(leaf.dtype) != str(arr.dtype):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def prune(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
