"""Elastic fault tolerance: failure detection -> re-mesh -> reshard -> resume.

On real fleets the runtime learns about lost hosts from the coordinator; here
`surviving_mesh` rebuilds the largest power-of-two mesh from whatever devices
remain, and resume is checkpoint-restore under the new mesh's shardings (see
checkpoint/manager.restore(shardings=...)). The deterministic, step-indexed
data pipeline (data/pipeline.py) makes the resumed run bit-identical modulo
the re-tiling.

Recovery contract (1000+-node posture):
  1. heartbeat loss on host H -> controller broadcasts epoch bump
  2. all hosts abort in-flight step (steps are idempotent: params/opt are
     only committed at step end)
  3. controller builds surviving mesh (drop H's slice; shrink the data axis —
     the model axis is left intact so TP groups stay whole)
  4. every host restores the latest checkpoint under the new shardings
  5. training resumes at checkpoint step; lost optimizer progress is bounded
     by the checkpoint cadence
"""
from __future__ import annotations

from typing import Sequence

from jax.sharding import Mesh


def surviving_mesh(devices: Sequence, model_axis: int, *, pod_axis: int = 1) -> Mesh:
    """Largest (pod, data, model)-factorable mesh from surviving devices.

    Keeps `model_axis` fixed (TP groups must stay whole: expert/head shards
    are not re-partitionable without re-sharding params, which restore does
    anyway, but keeping TP fixed keeps the restored layout identical) and
    shrinks data parallelism to the largest fit.
    """
    n = len(devices)
    if n < model_axis:
        raise ValueError(f"cannot keep model axis {model_axis} with {n} devices")
    data_axis = n // model_axis
    # largest power of two <= data_axis keeps collective groups balanced
    data_axis = 1 << (data_axis.bit_length() - 1)
    use = devices[: pod_axis * data_axis * model_axis]
    import numpy as np

    arr = np.array(use).reshape(pod_axis, data_axis, model_axis) if pod_axis > 1 else np.array(
        use
    ).reshape(data_axis, model_axis)
    names = ("pod", "data", "model") if pod_axis > 1 else ("data", "model")
    return Mesh(arr, names)


def simulate_failures(devices: Sequence, lost: int) -> list:
    """Drop `lost` devices (the tail host's slice) — test harness hook."""
    return list(devices[: len(devices) - lost])


def global_batch_for(mesh: Mesh, per_device_batch: int) -> int:
    """Elastic batch scaling: keep per-device batch fixed, let the global
    batch track the surviving data-parallel width (linear-scaling rule)."""
    data = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data *= mesh.shape[ax]
    return per_device_batch * data
