"""Gradient compression: int8-quantised all-reduce with error feedback.

For the cross-pod data axes (the longest links at 512+ chips), gradients are
quantised to int8 with a per-tensor scale before the all-reduce; quantisation
error is carried in a residual and re-added next step (error feedback, which
keeps SGD convergence — Karimireddy et al., arXiv:1901.09847). Implemented as
a shard_map wrapper so the collective itself moves 4x fewer bytes (pjit's
automatic psum cannot change the wire format).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jax.Array, axis_name, residual: jax.Array):
    """Error-feedback int8 all-reduce mean over `axis_name` (inside shard_map)."""
    corrected = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(corrected)
    new_residual = corrected - dequantize_int8(q, scale)
    # int8 payload all-reduce: sum int32 accumulators of the int8 payload and
    # the (tiny) scales separately
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each participant quantised with its own scale; use the mean scale as the
    # shared dequant step (scales are psum'd, 4 bytes per tensor)
    mean = summed.astype(jnp.float32) * (scale_sum / n) / n
    return mean, new_residual


def make_compressed_grad_reduce(mesh, axis_names: tuple[str, ...]):
    """Returns reduce(grads, residuals) -> (mean_grads, new_residuals) mapped
    over the mesh; grads arrive replicated over axis_names' complement."""
    from jax.experimental.shard_map import shard_map

    def reduce_one(g, r):
        return compressed_psum_mean(g, axis_names, r)

    def reduce_tree(grads, residuals):
        return jax.tree.map(reduce_one, grads, residuals)


    def wrapped(grads, residuals):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_leaves(residuals)
        outs = []
        for g, r in zip(flat_g, flat_r):
            fn = shard_map(
                reduce_one,
                mesh=mesh,
                in_specs=(P(*[None] * g.ndim), P(*[None] * r.ndim)),
                out_specs=(P(*[None] * g.ndim), P(*[None] * r.ndim)),
                check_rep=False,
            )
            outs.append(fn(g, r))
        means = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        residx = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return means, residx

    return wrapped
