"""Sharding rules: logical parallelism axes -> concrete mesh axes.

One ShardingRules instance describes how a family shards on a given mesh:
  fsdp : axis (tuple) over which parameters/optimizer state are fully sharded
         (ZeRO-3 style) — ('pod','data') on the multi-pod mesh.
  tp   : tensor-parallel axis ('model') for head/ffn/expert/vocab sharding.
  batch: axes carrying the global batch.

Model code receives a rules object and calls rules.constrain(...) at block
boundaries; param_specs(cfg, rules) builds the parameter PartitionSpec tree.
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp: tuple[str, ...] | str | None
    tp: str | None
    batch: tuple[str, ...] | str | None

    @property
    def tp_size(self) -> int:
        if self.tp is None:
            return 1
        return int(self.mesh.shape[self.tp])

    @property
    def fsdp_size(self) -> int:
        if self.fsdp is None:
            return 1
        axes = (self.fsdp,) if isinstance(self.fsdp, str) else self.fsdp
        return int(math.prod(self.mesh.shape[a] for a in axes))

    @property
    def batch_size_divisor(self) -> int:
        if self.batch is None:
            return 1
        axes = (self.batch,) if isinstance(self.batch, str) else self.batch
        return int(math.prod(self.mesh.shape[a] for a in axes))

    def heads_axis(self, n_heads: int):
        return self.tp if (self.tp and n_heads % self.tp_size == 0) else None

    def ax(self, axis, dim: int):
        """axis if it evenly divides dim, else None (explicit in_shardings
        require divisibility; constraints inside jit do not)."""
        if axis is None:
            return None
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        size = int(math.prod(self.mesh.shape[a] for a in axes))
        return axis if dim % size == 0 else None

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_rules(mesh: Mesh) -> ShardingRules:
    """Default rules for a (pod?, data, model) mesh."""
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names) or None
    tp = "model" if "model" in names else None
    return ShardingRules(mesh=mesh, fsdp=batch, tp=tp, batch=batch)


def divisible_fsdp_axis(rules: ShardingRules, dim: int):
    """fsdp axes only when they divide dim (used for odd embedding rows)."""
    return rules.fsdp if dim % max(1, rules.fsdp_size) == 0 else None
