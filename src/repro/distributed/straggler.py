"""Straggler mitigation for synchronous SPMD training.

At 1000+ nodes the step time is the max over hosts; two mitigations ship:

1. Deterministic step-skip barrier: hosts exchange a 1-bit "on pace" flag via
   a tiny psum; when more than `quorum` hosts are behind the deadline the
   fleet deterministically skips to the next step boundary (the step-indexed
   data pipeline makes every host skip identically — no coordinator needed).

2. Backup-shard execution for the KNN-Index build sweeps: each level batch is
   padded to bucketed shapes, so a slow host's shard can be re-executed by
   its data-parallel neighbor from the same immutable level inputs (work is
   pure + idempotent); the scatter of duplicate rows is last-writer-wins with
   identical values.

The flag exchange is the only runtime cost: one f32 all-reduce per step,
amortised to noise. This module provides the in-step primitives; the policy
loop lives in launch/train.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def pace_flag(step_start: float, deadline_s: float) -> jnp.ndarray:
    """1.0 if this host hit its deadline, else 0.0 (host-side measurement)."""
    return jnp.asarray(1.0 if (time.monotonic() - step_start) <= deadline_s else 0.0)


def quorum_ok(flags_mean: jax.Array, quorum: float = 0.95) -> bool:
    """Fleet proceeds when >= quorum of hosts are on pace."""
    return bool(flags_mean >= quorum)


class StepTimer:
    """EWMA of step wall time; deadline = mean * tolerance."""

    def __init__(self, tolerance: float = 1.5, alpha: float = 0.1):
        self.mean: float | None = None
        self.tolerance = tolerance
        self.alpha = alpha

    def update(self, dt: float) -> None:
        self.mean = dt if self.mean is None else (1 - self.alpha) * self.mean + self.alpha * dt

    @property
    def deadline(self) -> float:
        return float("inf") if self.mean is None else self.mean * self.tolerance
