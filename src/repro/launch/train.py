"""End-to-end training driver (works on the container CPU with --smoke and on
real meshes unchanged): data pipeline -> jitted sharded train step ->
checkpoint/resume -> straggler barrier.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import manager as ckpt
from repro.configs.registry import get_arch
from repro.data import pipeline
from repro.distributed.sharding import make_rules
from repro.distributed.straggler import StepTimer
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import steps as steps_mod


def make_stream(arch, cfg, smoke: bool):
    if arch.family == "lm":
        b, s = (8, 64) if smoke else (256, 4096)
        return pipeline.LMStream(vocab=cfg.vocab, batch=b, seq=s)
    if arch.family == "recsys":
        b = 32 if smoke else 65536
        return pipeline.RecsysStream(
            n_sparse=cfg.n_sparse, bag=cfg.bag_size, rows=cfg.table_rows, batch=b
        )
    if arch.family == "gnn":
        b = 8 if smoke else 128
        d_feat = getattr(cfg, "d_feat", 0)
        return pipeline.GraphStream(n_nodes=12, n_edges=32, batch=b, d_feat=d_feat)
    raise ValueError(arch.family)


def main():  # replint: disable=REP003(one-shot setup at process start; step_fn lives for the whole training run)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family == "gnn":
        cfg = arch.make_smoke() if args.smoke else arch.make_config("molecule")
    else:
        cfg = arch.make_smoke() if args.smoke else arch.make_config()
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    rules = make_rules(mesh)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10))

    stream = make_stream(arch, cfg, args.smoke)
    if arch.family == "lm":
        fn, in_specs, out_specs, _ = steps_mod.make_lm_train(cfg, rules, opt_cfg)
        import functools

        from repro.models import transformer as tr

        init = lambda: tr.init_params(jax.random.PRNGKey(0), cfg)
    elif arch.family == "recsys":
        fn, in_specs, out_specs, _ = steps_mod.make_recsys_train(cfg, rules, opt_cfg)
        from repro.models import recsys as rc

        init = lambda: rc.init_params(jax.random.PRNGKey(0), cfg)
    else:
        batch0 = jax.tree.map(jax.numpy.asarray, stream.batch_at(0))
        fn, in_specs, out_specs, _ = steps_mod.make_gnn_train(
            arch.arch_id, cfg, rules, batch0, opt_cfg
        )
        mod = steps_mod.GNN_MODULES[arch.arch_id]
        init = lambda: mod.init_params(jax.random.PRNGKey(0), cfg)

    params = init()
    opt_state = adamw.init(params)
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt.restore(args.ckpt_dir, (params, opt_state))
        params = jax.tree.map(jax.numpy.asarray, params)
        opt_state = jax.tree.map(jax.numpy.asarray, opt_state)
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(fn, donate_argnums=(0, 1))
    timer = StepTimer()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.monotonic()
        batch = jax.tree.map(jax.numpy.asarray, stream.batch_at(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        timer.update(time.monotonic() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"dt {timer.mean:.3f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
            ckpt.prune(args.ckpt_dir)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state))
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
