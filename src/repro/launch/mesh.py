"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(1, data)))
    return jax.make_mesh((data, model), ("data", "model"))
