"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Must be run as a module:  PYTHONPATH=src python -m repro.launch.dryrun --all
The XLA device-count override below MUST precede every other import.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " --xla_force_host_platform_device_count=512").strip()

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.common import SDS, ArchSpec, ShapeCell
from repro.configs.registry import all_cells, get_arch
from repro.distributed.sharding import make_rules
from repro.launch.hlo_analysis import collective_stats, memory_stats, summarize_cost
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.train import steps

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: ArchSpec, shape_name: str, cell: ShapeCell, rules,
               variant: dict | None = None):
    """Returns (fn, in_specs, out_specs, abstract_args, donate_argnums).

    `variant` carries §Perf hillclimb knobs:
      lm:  attn_probs_bf16=1, remat_policy=dots
      gnn: node_shard=all, gnn_bf16=1
      knn: knn_donate=1, knn_bf16=1, knn_vk_sharded=1
    """
    import dataclasses as _dc

    import jax.numpy as jnp

    variant = variant or {}
    if arch.family == "gnn":
        cfg = arch.make_config(shape_name)
        if variant.get("gnn_bf16"):
            cfg = _dc.replace(cfg, param_dtype=jnp.bfloat16)
    else:
        cfg = arch.make_config()
        if arch.family == "lm":
            over = {}
            if variant.get("attn_probs_bf16"):
                over["attn_probs_bf16"] = True
            if variant.get("remat_policy"):
                over["remat_policy"] = variant["remat_policy"]
            if variant.get("q_chunk"):
                over["q_chunk"] = int(variant["q_chunk"])
            if variant.get("kv_chunk"):
                over["kv_chunk"] = int(variant["kv_chunk"])
            if variant.get("capacity_factor"):
                over["capacity_factor"] = float(variant["capacity_factor"])
            if over:
                cfg = _dc.replace(cfg, **over)
    specs = cell.specs(cfg)

    if arch.family == "lm":
        if cell.kind == "train":
            fn, ins, outs, (params_abs, opt_abs) = steps.make_lm_train(cfg, rules)
            batch = {"tokens": specs["tokens"], "labels": specs["labels"]}
            return fn, ins, outs, (params_abs, opt_abs, batch)
        if cell.kind == "prefill":
            fn, ins, outs, (params_abs,) = steps.make_lm_prefill(cfg, rules, specs["max_len"])
            return fn, ins, outs, (params_abs, specs["tokens"])
        if cell.kind == "decode":
            drules = rules
            if variant.get("serve_fsdp") == "none":
                drules = _dc.replace(rules, fsdp=None)  # replicate over data at serve
            fn, ins, outs, (params_abs, cache_abs) = steps.make_lm_decode(
                cfg, drules, specs["cache_batch"], specs["cache_len"],
                cache_layout=variant.get("cache_layout", "auto"),
            )
            return fn, ins, outs, (params_abs, cache_abs, specs["tokens"])

    if arch.family == "gnn":
        batch = {k: v for k, v in specs.items()}
        fn, ins, outs, (params_abs, opt_abs) = steps.make_gnn_train(
            arch.arch_id, cfg, rules, batch,
            node_shard=variant.get("node_shard", "batch"),
        )
        return fn, ins, outs, (params_abs, opt_abs, batch)

    if arch.family == "recsys":
        if cell.kind == "train":
            fn, ins, outs, (params_abs, opt_abs) = steps.make_recsys_train(cfg, rules)
            batch = {"sparse_ids": specs["sparse_ids"], "labels": specs["labels"]}
            return fn, ins, outs, (params_abs, opt_abs, batch)
        if cell.kind == "forward":
            fn, ins, outs, (params_abs,) = steps.make_recsys_forward(cfg, rules)
            batch = {"sparse_ids": specs["sparse_ids"], "labels": specs["labels"]}
            return fn, ins, outs, (params_abs, batch)
        if cell.kind == "retrieval":
            fn, ins, outs, (params_abs,) = steps.make_recsys_retrieval(
                cfg, rules, specs["n_candidates"]
            )
            return fn, ins, outs, (params_abs, {"sparse_ids": specs["sparse_ids"]})

    if arch.family == "knn":
        if variant.get("knn_bf16"):
            specs = {
                k: SDS(v.shape, jnp.bfloat16) if v.dtype == jnp.float32 else v
                for k, v in specs.items()
            }
        if cell.kind == "knn_build":
            contig = bool(variant.get("knn_contig"))
            fn, ins, outs, _ = steps.make_knn_build(cfg, rules, contiguous=contig)
            if variant.get("knn_vk_sharded"):
                flat = tuple(rules.mesh.axis_names)
                ins = ins[:5] + (P(flat, None), P(flat, None))
                outs = (P(flat, None), P(flat, None))
            args = tuple(specs[k] for k in ("verts", "nbr", "w", "extra_ids", "extra_d", "vk_ids", "vk_d"))
            if contig:
                args = (SDS((), jnp.int32),) + args[1:]
            return fn, ins, outs, args
        if cell.kind == "knn_serve":
            fn, ins, outs, _ = steps.make_knn_serve(cfg, rules)
            args = tuple(specs[k] for k in ("vk_ids", "vk_d", "queries"))
            return fn, ins, outs, args

    raise ValueError(f"unhandled cell {arch.arch_id}/{shape_name} kind={cell.kind}")


def run_cell(arch: ArchSpec, shape_name: str, cell: ShapeCell, *, multi_pod: bool,  # replint: disable=REP003(one jit per dry-run cell by design; the wrapper is used once and discarded)
             out_dir: Path, variant: dict | None = None, tag: str = "") -> dict:
    variant = variant or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = make_rules(mesh)
    fn, in_specs, out_specs, abstract_args = build_cell(
        arch, shape_name, cell, rules, variant
    )

    donate = ()
    if arch.family == "knn" and cell.kind == "knn_build" and variant.get("knn_donate"):
        donate = (5, 6)
    if arch.family == "lm" and cell.kind == "decode" and variant.get("decode_donate"):
        donate = (1,)  # serving loops donate the KV cache
    jitted = jax.jit(
        fn,
        in_shardings=_shardings(mesh, in_specs),
        out_shardings=_shardings(mesh, out_specs) if out_specs is not None else None,
        donate_argnums=donate,
    )
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    cost = summarize_cost(compiled.cost_analysis())
    mem = memory_stats(compiled)
    coll_raw = collective_stats(hlo_text)
    # loop-corrected structural model (cost_analysis counts while bodies once)
    struct = hlo_analyze(hlo_text)

    flops_dev = struct["flops"]
    bytes_dev = struct["traffic_bytes"]
    coll_dev = struct["total_collective_bytes"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    rec = {
        "arch": arch.arch_id,
        "shape": shape_name,
        "kind": cell.kind,
        "variant": variant,
        "tag": tag,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "flops": flops_dev,
            "hbm_bytes": bytes_dev,
            "collective_bytes": coll_dev,
        },
        "collectives": {
            "bytes_per_device": struct["collective_bytes"],
            "counts": struct["collective_counts"],
            "total_bytes_per_device": coll_dev,
        },
        "loops": struct["loops"],
        "raw_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes_accessed", 0.0),
            "collective_bytes_unrolled": coll_raw["total_bytes_per_device"],
        },
        "memory": mem,
        "roofline_terms_s": terms,
        "bottleneck": bottleneck,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch.arch_id}__{shape_name}__{rec['mesh']}"
    if tag:
        fname += f"__{tag}"
    (out_dir / f"{fname.replace('/', '_')}.json").write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--include-skipped", action="store_true")
    ap.add_argument("--variant", default="", help="k=v,... §Perf hillclimb knobs")
    ap.add_argument("--tag", default="", help="artifact suffix for variant runs")
    args = ap.parse_args()
    variant = dict(kv.split("=", 1) for kv in args.variant.split(",") if kv)

    out_dir = Path(args.out)
    cells = []
    if args.all:
        cells = all_cells(include_skipped=args.include_skipped)
    else:
        arch = get_arch(args.arch)
        for shape, cell in arch.shapes.items():
            if args.shape and shape != args.shape:
                continue
            if cell.skip and not args.include_skipped and args.shape != shape:
                continue
            cells.append((arch, shape, cell))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape, cell in cells:
        if cell.skip and not args.include_skipped:
            print(f"SKIP  {arch.arch_id:<24} {shape:<14} ({cell.skip})")
            continue
        for mp in meshes:
            tag = f"{arch.arch_id}/{shape} mesh={'2x16x16' if mp else '16x16'}"
            if args.tag:
                tag += f" [{args.tag}]"
            try:
                rec = run_cell(arch, shape, cell, multi_pod=mp, out_dir=out_dir,
                               variant=variant, tag=args.tag)
                t = rec["roofline_terms_s"]
                print(
                    f"OK    {tag:<52} compile={rec['compile_s']:>7.1f}s "
                    f"compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s "
                    f"coll={t['collective_s']:.3e}s -> {rec['bottleneck']}"
                )
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL  {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
