"""Structural HLO cost model with while-loop trip-count correction.

XLA's compiled.cost_analysis() counts each while-loop body ONCE — a 64x
undercount for a 64-iteration scan (verified in tests) — and the same bias
hits collective bytes parsed naively from the HLO text. This module parses
the post-SPMD HLO into its computation graph, reads loop trip counts from
the `known_trip_count` backend config (fallback: the loop-condition compare
constant), and propagates multipliers down the call graph, yielding
loop-corrected per-device:

  flops             dot-op FLOPs (2 * prod(out_dims) * prod(contract_dims));
                    matmuls dominate every model family here
  traffic_bytes     memory traffic: operand + output bytes of materialising
                    instructions (fusion-boundary granularity)
  collective_bytes  per-collective-kind result bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\([^)]*\)|\w+\[[\d,]*\])")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+([a-z][\w\-]*)\((.*)$"
)
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_CONST_RE = re.compile(r"%([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "after-all",
    "partition-id", "replica-id", "iota", "domain", "opt-barrier",
}

# ops whose own operand/result tuples are not data movement (loop carries stay
# in place; the body's inserted copies are counted where they occur)
_CONTROL_OPS = {"while", "conditional", "call"}


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(text: str) -> int:
    return sum(
        _elems(dims) * _DTYPE_BYTES[dt]
        for dt, dims in _SHAPE_RE.findall(text)
        if dt in _DTYPE_BYTES
    )


@dataclass
class _Instr:
    name: str
    out_type: str
    op: str
    rest: str


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # symbol -> type text
    consts: dict = field(default_factory=dict)


def _split(text: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if "->" in line and line.endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.shapes[pname] = ptype
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, out_type, op, rest = mi.groups()
        cur.shapes[name] = out_type
        cur.instrs.append(_Instr(name, out_type, op, rest))
        mc = _CONST_RE.match(line.lstrip("ROOT ").strip())
        if mc:
            cur.consts[mc.group(1)] = int(mc.group(2))
    return comps, entry


def _trip_count(line_rest: str, comps: dict[str, _Comp]) -> int:
    mt = _TRIP_RE.search(line_rest)
    if mt:
        return max(1, int(mt.group(1)))
    mc = _COND_RE.search(line_rest)
    if mc and mc.group(1) in comps:
        consts = comps[mc.group(1)].consts
        if consts:
            return max(1, max(consts.values()))
    return 1


_REDUCE_OPS = {"reduce", "reduce-window", "scatter", "select-and-scatter", "sort"}
_SLICE_OPS = {"dynamic-slice", "gather", "slice", "dynamic-update-slice"}


def _fusion_flags(rest: str, comps: dict[str, _Comp]) -> str:
    """'slice' if the fused computation only windows its operands (no full
    reduction), else 'full'."""
    for callee in _CALL_RE.findall(rest):
        c = comps.get(callee)
        if c is None:
            continue
        ops = {i.op for i in c.instrs}
        if ops & _REDUCE_OPS:
            return "full"
        if ops & _SLICE_OPS:
            return "slice"
    return "full"


def analyze(hlo_text: str) -> dict:
    comps, entry = _split(hlo_text)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "collective_bytes": {},
                "collective_counts": {}, "total_collective_bytes": 0.0, "loops": {}}

    memo: dict[str, tuple] = {}
    visiting: set[str] = set()
    loops: dict[str, int] = {}

    def walk(name: str) -> tuple[float, float, dict, dict]:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return 0.0, 0.0, {}, {}
        visiting.add(name)
        c = comps[name]
        flops = 0.0
        traffic = 0.0
        coll: dict[str, float] = defaultdict(float)
        coll_n: dict[str, int] = defaultdict(int)
        for ins in c.instrs:
            if ins.op in _FREE_OPS:
                continue
            rest = ins.rest.split(", metadata=")[0]
            arg_text = rest.split(")", 1)[0]
            operand_names = _OPERAND_RE.findall(arg_text)
            out_b = _type_bytes(ins.out_type)
            in_b = sum(_type_bytes(c.shapes.get(o, "")) for o in operand_names)
            # slice-streaming ops read only output-sized windows of their
            # operands (KV-cache updates, scan weight slicing); charging the
            # full operand per loop iteration would overcount by the trip count
            if ins.op in ("dynamic-slice", "gather", "slice"):
                in_b = min(in_b, 2 * out_b)
            elif ins.op == "dynamic-update-slice" and len(operand_names) >= 2:
                upd = _type_bytes(c.shapes.get(operand_names[1], ""))
                if upd:  # in-place DUS: read + write the updated window only
                    in_b, out_b = 2 * upd, upd
            elif ins.op == "fusion":
                flags = _fusion_flags(rest, comps)
                if flags == "slice":
                    in_b = min(in_b, 2 * out_b)
            if ins.op in _CONTROL_OPS:
                in_b = out_b = 0
            traffic += out_b + in_b
            # dot flops
            if ins.op in ("dot", "dot-general") or ins.op.startswith("dot"):
                md = _CDIMS_RE.search(rest)
                if md and operand_names:
                    lhs_type = c.shapes.get(operand_names[0], "")
                    ms = _SHAPE_RE.search(lhs_type)
                    if ms:
                        lhs_dims = [int(d) for d in ms.group(2).split(",") if d]
                        contract = 1
                        for i in (int(x) for x in md.group(1).split(",") if x):
                            if i < len(lhs_dims):
                                contract *= lhs_dims[i]
                        out_elems = sum(
                            _elems(d) for _, d in _SHAPE_RE.findall(ins.out_type)
                        )
                        flops += 2.0 * out_elems * contract
            # collectives
            base = ins.op.removesuffix("-start").removesuffix("-done")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                coll[base] += out_b
                coll_n[base] += 1
            # calls
            if ins.op == "while":
                body = _CALL_RE.search(rest)
                trips = _trip_count(rest, comps)
                if body:
                    if trips > 1:
                        loops[body.group(1)] = trips
                    sf, st, scb, scn = walk(body.group(1))
                    flops += sf * trips
                    traffic += st * trips
                    for k, v in scb.items():
                        coll[k] += v * trips
                    for k, v in scn.items():
                        coll_n[k] += v * trips
            elif ins.op == "fusion":
                # traffic at fusion boundary is already counted; fused dots
                # still need flops credit
                for callee in _CALL_RE.findall(rest):
                    sf, _, scb, scn = walk(callee)
                    flops += sf
                    for k, v in scb.items():
                        coll[k] += v
                    for k, v in scn.items():
                        coll_n[k] += v
            elif ins.op in ("call", "conditional", "custom-call", "map",
                            "reduce", "reduce-window", "scatter", "sort",
                            "select-and-scatter", "all-reduce", "all-reduce-start"):
                for callee in _CALL_RE.findall(rest):
                    sf, st, scb, scn = walk(callee)
                    flops += sf
                    traffic += st if ins.op in ("call", "conditional") else 0.0
                    for k, v in scb.items():
                        coll[k] += v
                    for k, v in scn.items():
                        coll_n[k] += v
        visiting.discard(name)
        memo[name] = (flops, traffic, dict(coll), dict(coll_n))
        return memo[name]

    f, t, cb, cn = walk(entry)
    return {
        "flops": f,
        "traffic_bytes": t,
        "collective_bytes": cb,
        "collective_counts": cn,
        "total_collective_bytes": float(sum(cb.values())),
        "loops": loops,
    }
