"""Post-SPMD HLO analysis: collective byte accounting for the roofline.

cost_analysis() gives FLOPs/bytes but not collective traffic; we parse the
partitioned HLO text and sum the result-shape bytes of every collective op.
Shapes in the partitioned module are per-device shards, so totals here are
per-device collective bytes (multiply by chip count for fleet-global).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective bytes and op counts, keyed by collective kind."""
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_text, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async start/done pairs: count the start only
        b = _shape_bytes(shape_text)
        bytes_by[op] += b
        count_by[op] += 1
    return {
        "bytes_per_device": dict(bytes_by),
        "counts": dict(count_by),
        "total_bytes_per_device": int(sum(bytes_by.values())),
    }


def summarize_cost(cost: dict | list | None) -> dict:
    """Normalise compiled.cost_analysis() output across jax versions."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for key in ("flops", "bytes accessed", "optimal_seconds", "utilization operand"):
        if key in cost:
            out[key.replace(" ", "_")] = float(cost[key])
    for k, v in cost.items():
        if k.startswith("bytes accessed"):
            out.setdefault("bytes_accessed_total", 0.0)
    return out


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out
