"""KNN-Index production build driver (the paper's pipeline, end to end):

  road network -> min-degree order + BN-Graph (host symbolic phase)
               -> level-synchronous device sweeps (bottom-up V_k^<, top-down V_k)
               -> QueryEngine artifact + stats

  PYTHONPATH=src python -m repro.launch.knn_build --grid 80 --k 20 --mu 0.05 \
      --out index.npz

The build goes through the ``repro.knn`` facade and the ``--out`` artifact is
``QueryEngine.save`` format, so ``serve.py --arch knn-index --artifact`` (and
``knn.load_engine``) round-trip through one file.
"""
from __future__ import annotations

import argparse
import json
import time

from repro import knn
from repro.core.construct_jax import build_knn_tables_jax, prepare_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=60, help="grid side; n = grid^2")
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--mu", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--verify", action="store_true", help="check vs host reference")
    ap.add_argument("--out", default=None, help="write a QueryEngine.save npz")
    args = ap.parse_args()

    t0 = time.perf_counter()
    g = knn.road_network(args.grid, args.grid, seed=args.seed)
    objects = knn.pick_objects(g.n, args.mu, seed=args.seed)
    t1 = time.perf_counter()
    bn = knn.build_bngraph(g)
    t2 = time.perf_counter()
    # prepare the sweep schedules once: they drive the build AND the stats
    up = prepare_sweep(bn, "up")
    down = prepare_sweep(bn, "down")
    vk_ids, vk_d = build_knn_tables_jax(
        bn, objects, args.k, use_pallas=args.use_pallas, plans=(up, down)
    )
    engine = knn.QueryEngine(
        vk_ids, vk_d, args.k, objects, bn=bn, use_pallas=args.use_pallas
    )
    t3 = time.perf_counter()
    idx = engine.to_index()
    stats = {
        "n": g.n,
        "m": g.m,
        "|M|": int(objects.size),
        "k": args.k,
        "rho": bn.rho,
        "tau": bn.tau,
        "levels_up": up.num_levels,
        "levels_down": down.num_levels,
        "chunks_up": up.num_chunks,
        "chunks_down": down.num_chunks,
        "shape_buckets_up": len(up.buckets),
        "shape_buckets_down": len(down.buckets),
        "pad_occupancy_up": round(up.occupancy, 4),
        "pad_occupancy_down": round(down.occupancy, 4),
        "gen_s": round(t1 - t0, 3),
        "bngraph_s": round(t2 - t1, 3),
        "sweeps_s": round(t3 - t2, 3),
        # the paper's n*k*(4+4)-byte count = what the device tables occupy
        "index_bytes": idx.size_bytes(dist_bytes=4),
    }
    if args.verify:
        from repro.core.verify import certificate

        ref = knn.knn_index_cons_plus(bn, objects, args.k)
        stats["verified"] = bool(knn.indices_equivalent(ref, idx))
        if g.n <= 20000:  # dense tropical certificate at verification scale
            stats["bngraph_certificate"] = certificate(bn, use_pallas=False)
    print(json.dumps(stats, indent=2))
    if args.out:
        engine.save(args.out)
    return stats


if __name__ == "__main__":
    main()
