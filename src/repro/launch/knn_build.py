"""KNN-Index production build driver (the paper's pipeline, end to end):

  road network -> min-degree order + BN-Graph (host symbolic phase)
               -> level-synchronous device sweeps (bottom-up V_k^<, top-down V_k)
               -> index artifact + stats

  PYTHONPATH=src python -m repro.launch.knn_build --grid 80 --k 20 --mu 0.05
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.bngraph import build_bngraph
from repro.core.construct_jax import build_knn_index_jax, prepare_sweep
from repro.core.reference import knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=60, help="grid side; n = grid^2")
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--mu", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--verify", action="store_true", help="check vs host reference")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    t0 = time.perf_counter()
    g = road_network(args.grid, args.grid, seed=args.seed)
    objects = pick_objects(g.n, args.mu, seed=args.seed)
    t1 = time.perf_counter()
    bn = build_bngraph(g)
    t2 = time.perf_counter()
    idx = build_knn_index_jax(bn, objects, args.k, use_pallas=args.use_pallas)
    t3 = time.perf_counter()

    up = prepare_sweep(bn, "up")
    down = prepare_sweep(bn, "down")
    stats = {
        "n": g.n,
        "m": g.m,
        "|M|": int(objects.size),
        "k": args.k,
        "rho": bn.rho,
        "tau": bn.tau,
        "levels_up": up.num_levels,
        "levels_down": down.num_levels,
        "chunks_up": up.num_chunks,
        "chunks_down": down.num_chunks,
        "shape_buckets_up": len(up.buckets),
        "shape_buckets_down": len(down.buckets),
        "pad_occupancy_up": round(up.occupancy, 4),
        "pad_occupancy_down": round(down.occupancy, 4),
        "gen_s": round(t1 - t0, 3),
        "bngraph_s": round(t2 - t1, 3),
        "sweeps_s": round(t3 - t2, 3),
        "index_bytes": idx.size_bytes(),
    }
    if args.verify:
        ref = knn_index_cons_plus(bn, objects, args.k)
        from repro.core.index import indices_equivalent
        from repro.core.verify import certificate

        stats["verified"] = bool(indices_equivalent(ref, idx))
        if g.n <= 20000:  # dense tropical certificate at verification scale
            stats["bngraph_certificate"] = certificate(bn, use_pallas=False)
    print(json.dumps(stats, indent=2))
    if args.out:
        np.savez(args.out, ids=idx.ids, dists=idx.dists, k=args.k)
    return stats


if __name__ == "__main__":
    main()
