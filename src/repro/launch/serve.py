"""Serving driver, dispatched by architecture family.

LM archs — batched prefill + autoregressive decode loop:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --prompt-len 32 --gen 16 --batch 4

kNN archs — device-resident ``QueryEngine`` loop under mixed traffic
(batched queries + staged object updates, the paper's BUA arrival model):

  PYTHONPATH=src python -m repro.launch.serve --arch knn-index --smoke \
      --batch 1024 --ops 50000 --update-frac 0.05

The kNN loop builds (or loads, --artifact) the index, then serves rounds of
``query_batch`` with updates staged into the engine's queue and flushed once
per round, printing queries/s, updates/s and the engine's serving stats as
JSON. On the CPU container use --smoke.

``--workload fleet`` swaps the random insert/delete churn for the
moving-objects workload: a ``FleetSim`` drives vehicles along shortest-path
trips, each serving tick stages the tick's (src, dst) moves via
``stage_move`` and flushes them as one fused device batch while query
batches interleave. Reports sustained ticks/s and query p50/p99:

  PYTHONPATH=src python -m repro.launch.serve --arch knn-index --smoke \
      --workload fleet --fleet-size 96 --ticks 50 --batch 256

``--shards N`` serves from the vertex-sharded multi-device engine
(``ShardedQueryEngine``) instead — same results, tables row-partitioned
across N devices. On CPU, force the device count first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch knn-index --smoke \
      --shards 8 --batch 1024 --ops 20000

``--seed`` seeds everything host-side — the network, the object draw, the
query stream AND the staged-update stream (it threads into
``knn.stage_random_updates`` / ``FleetSim``), so two runs with the same seed
serve the identical op sequence; the default seed is 0.

``--replicate SHARD:R`` (sharded engine only) replicates one shard's epoch
buffers onto R extra devices and fans its queries across the replica set —
the answer to skewed traffic where one owner device is the ceiling.
``--replicate auto:R`` instead watches a sliding per-shard query histogram
and replicates whichever shard is hottest once the warmup rounds have
seen enough traffic. ``--hot-shard S --hot-frac F`` skews the synthetic
query stream so F of each batch lands in shard S's vertex range (the
zipf-city downtown); a replica failure mid-batch degrades that batch to
the primary path and counts ``replica_errors`` in the engine stats
instead of failing the run:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch knn-index --smoke \
      --shards 4 --hot-shard 0 --hot-frac 0.8 --replicate auto:3

``--partition SPEC`` is the unified layout surface that replaces
``--shards``/``--replicate`` (both kept as deprecation shims; mixing them
with --partition is an error). One spec names the whole partition layout —
shard count, range boundaries, replication and routing policy:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch knn-index --smoke \
      --partition shards=4,ranges=auto --hot-shard 0 --hot-frac 0.9

``ranges=auto`` watches the same sliding query histogram the auto-replica
watcher uses, but per *vertex*, as a continuous drift detector: whenever
the window's balance ratio decays past ``--rebalance-ratio`` it proposes
traffic-balanced boundaries (``propose_starts``) and repartitions on the
next flush — pinned readers on old epochs keep their old boundaries, new
queries route by the new ones — then keeps watching, so a traffic shift
mid-run (``--hot-flip-round``) triggers a second re-split after the
cooldown. The JSON stats report the active plan under ``"partition"`` and
the re-split history under ``"repartition_rounds"``.

``--compile-cache DIR`` (or ``REPRO_COMPILE_CACHE``) persists compiled XLA
executables across processes, so a cold boot over a warm cache dir skips
the expensive compiles.
"""
from __future__ import annotations

import argparse
import json
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch


def serve_lm(args) -> np.ndarray:  # replint: disable=REP003(one-shot setup at process start; prefill/decode wrappers live for the whole serving run)
    """Batched prefill + decode loop (GQA grouped-einsum attention, sharded
    KV cache) — the same steps the dry-run lowers for prefill/decode cells."""
    from repro.distributed.sharding import make_rules
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tr

    arch = get_arch(args.arch)
    cfg = arch.make_smoke() if args.smoke else arch.make_config()
    mesh = make_host_mesh(data=len(jax.devices()))
    rules = make_rules(mesh)

    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    prefill = jax.jit(lambda p, t: tr.prefill(p, t, cfg, max_len, rules))
    decode = jax.jit(lambda p, c, t: tr.decode_step(p, c, t, cfg, rules),
                     donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for step in range(args.gen - 1):
        logits, cache = decode(params, cache, tokens)
        if args.temperature > 0:
            key = jax.random.PRNGKey(100 + step)
            tokens = jax.random.categorical(key, logits / args.temperature, -1).astype(jnp.int32)
        else:
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"model {cfg.name}: prefill({args.batch}x{args.prompt_len}) "
          f"{t_prefill * 1e3:.1f} ms; decode {args.gen - 1} steps "
          f"{t_decode * 1e3:.1f} ms ({tps:.1f} tok/s)")
    print("generated token ids (first sequence):", out[0].tolist())
    return out


def _knn_partition_plan(args):
    """Resolve ``--partition`` vs the legacy ``--shards``/``--replicate``
    flags into one ``PartitionPlan`` (None = scalar engine)."""
    from repro import knn

    if args.partition:
        if args.shards or args.replicate:
            raise SystemExit(
                "--partition replaces --shards/--replicate: name the whole "
                "layout in one spec, e.g. --partition shards=4,replicate=auto:2"
            )
        try:
            plan = knn.PartitionPlan.parse(args.partition)
        except knn.EngineConfigError as e:
            raise SystemExit(f"--partition: {e}")
        if plan.shards is None:
            raise SystemExit("--partition must name shards=N")
        return plan
    if not args.shards:
        if args.replicate:
            raise SystemExit(
                "--replicate / --partition replication need the sharded "
                "engine (--shards N or --partition shards=N)"
            )
        return None
    rep = _parse_replicate(args.replicate) if args.replicate else None
    replication = None
    if rep is not None:
        replication = rep if rep[0] == "auto" else (rep,)
    return knn.PartitionPlan(shards=args.shards, replication=replication)


def _build_knn_engine(args, bn, objects, k: int, plan=None):
    """Scalar or sharded engine, per the resolved partition plan (the
    serving loops are engine-agnostic: both expose the same
    query/stage/flush surface)."""
    from repro import knn

    if plan is not None:
        return knn.build_sharded_engine(
            bn, objects, k, plan=plan, use_pallas=args.use_pallas
        )
    return knn.QueryEngine.build(bn, objects, k, use_pallas=args.use_pallas)


def serve_knn_fleet(args, g, bn, k: int, batch: int, t_bn: float, plan=None) -> dict:
    """Moving-fleet serving loop: fused ``stage_move`` flushes per tick."""
    from repro import knn
    from repro.workloads import drive_fleet_ticks

    sim = knn.FleetSim(g, fleet_size=args.fleet_size, seed=args.seed)
    t0 = time.perf_counter()
    engine = _build_knn_engine(args, bn, sim.positions, k, plan=plan)
    t_build = time.perf_counter() - t0

    rng = np.random.default_rng(args.seed + 1)
    # warmup: compile the gather once outside the timed loop
    jax.block_until_ready(engine.query_batch(rng.integers(0, g.n, size=batch))[0])

    r = drive_fleet_ticks(
        engine, (sim.tick() for _ in range(args.ticks)), batch=batch, rng=rng
    )
    wall, lat = r["wall_s"], r["lat"]

    stats = {
        "arch": get_arch(args.arch).arch_id,
        "workload": "fleet",
        "n": g.n,
        "k": k,
        "batch": batch,
        "fleet_size": sim.fleet_size,
        "ticks": args.ticks,
        "bngraph_s": round(t_bn, 3),
        "build_s": round(t_build, 3),
        "ticks_per_s": round(args.ticks / max(wall, 1e-9), 2),
        "moves_per_tick": round(sim.moves_total / max(args.ticks, 1), 1),
        "queries_per_s": round(args.ticks * batch / max(sum(lat), 1e-9), 1),
        "query_p50_us": round(float(np.percentile(lat, 50)) * 1e6, 1),
        "query_p99_us": round(float(np.percentile(lat, 99)) * 1e6, 1),
        "partition": engine.partition_plan().describe() if plan is not None else None,
        "sim": sim.stats(),
        "engine": engine.stats(),
    }
    print(json.dumps(stats, indent=2))
    return stats


def _parse_replicate(spec: str) -> tuple:
    """``SHARD:R`` -> (shard, R); ``auto:R`` -> ("auto", R)."""
    try:
        shard_s, _, r_s = spec.partition(":")
        r = int(r_s)
        if r < 1:
            raise ValueError
        return ("auto", r) if shard_s == "auto" else (int(shard_s), r)
    except ValueError:
        raise SystemExit(f"--replicate wants SHARD:R or auto:R (R >= 1), got {spec!r}")


def _hot_range(engine, shard: int, n: int) -> tuple[int, int]:
    """The hot shard's vertex range, read from the live routing boundaries
    (under uneven or repartitioned ranges the shards are not equal-width
    slices — always derive the range from ``engine.routing.starts``)."""
    starts = engine.routing.starts
    shard = shard % len(starts)
    lo = int(starts[shard])
    hi = int(starts[shard + 1]) if shard + 1 < len(starts) else n
    return (min(lo, n - 1), min(max(hi, lo + 1), n))


def _draw_queries(rng, n: int, batch: int, hot_range, hot_frac: float) -> np.ndarray:
    """Uniform query batch, with ``hot_frac`` of it redirected into
    ``hot_range`` (the skewed-city traffic model exp16 benchmarks)."""
    us = rng.integers(0, n, size=batch)
    if hot_frac > 0 and hot_range is not None:
        m = rng.random(batch) < hot_frac
        us[m] = rng.integers(hot_range[0], hot_range[1], size=int(m.sum()))
    return us


def _arm_injected_flush_failure(engine) -> None:
    """One-shot fault: the next flush dies just before its epoch swap (the
    worst-case point — all the work done, nothing published). Exercises the
    degrade-gracefully path end to end from the CLI."""

    def hook(e, phase):
        if phase == "pre-swap":
            e.checkpoint_hook = None
            raise RuntimeError("injected flush failure (--inject-flush-failure)")

    engine.checkpoint_hook = hook


def serve_knn(args) -> dict:
    """kNN serving loop: batched queries + staged updates on a QueryEngine."""
    from repro import knn

    arch = get_arch(args.arch)
    cfg = arch.make_smoke() if args.smoke else arch.make_config()
    grid = args.grid or int(np.ceil(np.sqrt(cfg.n_vertices)))
    k = args.k or cfg.k

    batch = args.batch or min(cfg.query_batch, 4096)

    g = knn.road_network(grid, grid, seed=args.seed)
    objects = knn.pick_objects(g.n, args.mu, seed=args.seed)
    t0 = time.perf_counter()
    bn = knn.build_bngraph(g)
    t_bn = time.perf_counter() - t0
    plan = _knn_partition_plan(args)
    if args.workload == "fleet":
        if args.artifact:
            # the fleet engine's object set must equal the sim's vehicle
            # positions, which a saved artifact cannot know about
            raise SystemExit("--artifact cannot be combined with --workload fleet")
        return serve_knn_fleet(args, g, bn, k, min(batch, 4096), t_bn, plan=plan)
    t0 = time.perf_counter()
    if args.artifact:
        # The artifact must come from the same (grid, seed) network: the
        # engine stores tables + objects, the BN-Graph supplies adjacency.
        # A plan (or --shards) reshards it on load: the artifact stores the
        # logical vertex-order tables plus any uneven boundaries the writer
        # served under, reused when the shard count matches.
        engine = knn.load_engine(
            args.artifact, bn=bn, plan=plan, use_pallas=args.use_pallas,
        )
        if engine.n != g.n or engine.k != k:
            raise SystemExit(
                f"artifact shape (n={engine.n}, k={engine.k}) does not match "
                f"--grid/--k (n={g.n}, k={k})"
            )
    else:
        engine = _build_knn_engine(args, bn, objects, k, plan=plan)
    t_build = time.perf_counter() - t0

    if args.hot_frac and plan is None:
        raise SystemExit(
            "--hot-frac needs the sharded engine (--shards N or "
            "--partition shards=N)"
        )
    auto_reps = plan.auto_replicas() if plan is not None else 0
    replicated_shard = None
    if plan is not None and engine.routing.replication:
        # explicit plan replication was applied at build/load time
        replicated_shard = min(engine.routing.replication)
    hot_range = None
    if plan is not None and args.hot_frac:
        hot_range = _hot_range(engine, args.hot_shard, g.n)
    # sliding query histograms: per-shard owner counts pick the hot shard
    # for --replicate auto; the per-vertex window feeds the ranges=auto
    # drift detector (continuous re-splits, see below)
    hist: deque = deque(maxlen=16)
    auto_ranges = plan is not None and plan.ranges == "auto" and engine.num_shards > 1
    # ranges=auto is a continuous drift detector, not a one-shot warmup
    # split: a sliding per-vertex histogram window tracks live traffic, and
    # whenever its balance ratio (the hottest shard's share x S, 1.0 =
    # perfectly balanced) decays past --rebalance-ratio — the initial
    # unbalanced boundaries, or the zipf city moving after a traffic flip —
    # the splitter proposes fresh boundaries and the engine repartitions on
    # the next flush. --rebalance-cooldown rounds separate re-splits so one
    # drift doesn't thrash the layout while the window still mixes old and
    # new traffic.
    vwin: deque = deque(maxlen=args.rebalance_window)
    repartition_rounds: list[int] = []
    balance_ratio = None

    rng = np.random.default_rng(args.seed + 1)
    mset = set(engine.objects.tolist())
    n_upd_round = int(round(batch * args.update_frac))
    rounds = max(1, args.ops // (batch + n_upd_round))

    # warmup: compile the gather once outside the timed loop
    jax.block_until_ready(
        engine.query_batch(_draw_queries(rng, g.n, batch, hot_range, args.hot_frac))[0]
    )

    # A failed flush (device error, corrupted batch, injected fault) must
    # not kill serving: the engine rolls back to the last good epoch with
    # the staged queue intact, so we log it, keep answering queries, and
    # retry the accumulated queue next round. --fail-fast restores the old
    # die-on-first-error behavior for debugging.
    t_query = t_update = 0.0
    queries = updates = 0
    errors = 0
    last_error = None
    for rnd in range(rounds):
        if args.hot_flip_round and rnd + 1 == args.hot_flip_round:
            # the zipf city moves: re-aim the skewed traffic at another
            # shard's vertex range (read from the *current* boundaries,
            # which a prior re-split may have moved)
            flip_to = (
                args.hot_shard2
                if args.hot_shard2 is not None
                else (args.hot_shard + engine.num_shards // 2) % engine.num_shards
            )
            hot_range = _hot_range(engine, flip_to, g.n)
        us = _draw_queries(rng, g.n, batch, hot_range, args.hot_frac)
        t0 = time.perf_counter()
        ids, dists = engine.query_batch(us)
        jax.block_until_ready(ids)
        t_query += time.perf_counter() - t0
        queries += batch

        if auto_ranges:
            vwin.append(np.bincount(us, minlength=g.n))
            wsum = np.sum(vwin, axis=0)
            starts = engine.routing.starts
            bounds = np.append(starts, g.n)
            shares = np.add.reduceat(wsum, bounds[:-1])
            balance_ratio = float(
                shares.max() * engine.num_shards / max(wsum.sum(), 1)
            )
            cooled = (
                not repartition_rounds
                or rnd + 1 - repartition_rounds[-1] >= args.rebalance_cooldown
            )
            if (
                rnd + 1 >= 3  # enough warmup traffic to trust the window
                and cooled
                and balance_ratio > args.rebalance_ratio
            ):
                proposed = knn.propose_starts(wsum, engine.num_shards)
                if not np.array_equal(proposed, starts):
                    engine.repartition(proposed)  # rides a fresh epoch; old
                    repartition_rounds.append(rnd + 1)  # epochs keep theirs
                    hist.clear()  # owner counts now track the new boundaries

        if auto_reps and replicated_shard is None:
            hist.append(
                np.bincount(engine.routing.owner(us), minlength=engine.num_shards)
            )
            warmup = 3 if not auto_ranges else 6  # let ranges settle first
            if rnd + 1 >= warmup and hist:
                hot = int(np.argmax(np.sum(hist, axis=0)))
                engine.set_replication({hot: auto_reps}, policy=plan.policy)
                replicated_shard = hot

        if n_upd_round:
            t0 = time.perf_counter()
            knn.stage_random_updates(engine, mset, rng, n_upd_round)
            depth = engine.queue_depth
            if args.inject_flush_failure and rnd + 1 == args.inject_flush_failure:
                _arm_injected_flush_failure(engine)
            try:
                engine.flush_updates()
                updates += depth
            except Exception as e:
                if args.fail_fast:
                    raise
                errors += 1
                last_error = f"{type(e).__name__}: {e}"
            finally:
                engine.checkpoint_hook = None
            t_update += time.perf_counter() - t0

    wall = t_query + t_update
    stats = {
        "arch": arch.arch_id,
        "n": g.n,
        "k": k,
        "batch": batch,
        "rounds": rounds,
        "bngraph_s": round(t_bn, 3),
        "build_s": round(t_build, 3),
        "queries": queries,
        "updates": updates,
        "errors": errors,
        "last_error": last_error,
        "replicate": args.replicate,
        "replicated_shard": replicated_shard,
        "partition": engine.partition_plan().describe() if plan is not None else None,
        "repartitioned_at_round": (
            repartition_rounds[0] if repartition_rounds else None
        ),
        "repartition_rounds": repartition_rounds,
        "balance_ratio": round(balance_ratio, 4) if balance_ratio else None,
        "hot_frac": args.hot_frac,
        "queries_per_s": round(queries / max(t_query, 1e-9), 1),
        "updates_per_s": round(updates / max(t_update, 1e-9), 1) if updates else 0.0,
        "ops_per_s": round((queries + updates) / max(wall, 1e-9), 1),
        "us_per_query": round(t_query / max(queries, 1) * 1e6, 3),
        "engine": engine.stats(),
    }
    print(json.dumps(stats, indent=2))
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="lm: sequence batch (default 4); knn: query batch "
                         "(default min(config query_batch, 4096))")
    # --query-batch is an alias for --batch kept for the knn family
    ap.add_argument("--query-batch", type=int, default=None, dest="batch")
    # lm options
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # knn options
    ap.add_argument("--grid", type=int, default=None, help="grid side; n = grid^2")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--mu", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the network, object draw, query stream and "
                         "the staged-update stream (stage_random_updates / "
                         "FleetSim), so equal seeds replay identical traffic")
    ap.add_argument("--ops", type=int, default=50_000)
    ap.add_argument("--update-frac", type=float, default=0.05)
    ap.add_argument("--workload", choices=("random", "fleet"), default="random",
                    help="knn update traffic: random insert/delete churn or the "
                         "moving-fleet stage_move workload")
    ap.add_argument("--fleet-size", type=int, default=96)
    ap.add_argument("--ticks", type=int, default=50,
                    help="fleet workload: serving ticks (one flush per tick)")
    ap.add_argument("--artifact", default=None, help="serve a knn_build --out npz")
    ap.add_argument("--fail-fast", action="store_true",
                    help="knn: die on the first failed flush instead of "
                         "logging it (errors/last_error in the JSON stats) "
                         "and continuing on the last good epoch")
    ap.add_argument("--inject-flush-failure", type=int, default=0,
                    metavar="ROUND",
                    help="knn: make the flush of round ROUND fail just "
                         "before its epoch swap (fault-injection smoke for "
                         "the graceful-degradation path)")
    ap.add_argument("--partition", default=None, metavar="SPEC",
                    help="knn: the whole partition layout as one spec, e.g. "
                         "'shards=4,replicate=auto:2,ranges=auto' (keys: "
                         "shards, ranges [equal | auto | 0:B1:B2...], "
                         "replicate [SHARD:R | auto:R], policy). ranges=auto "
                         "repartitions on flush from the sliding query "
                         "histogram. Replaces --shards/--replicate")
    ap.add_argument("--shards", type=int, default=0,
                    help="[deprecated: use --partition shards=N] serve from "
                         "the vertex-sharded multi-device engine with this "
                         "many shards (0 = scalar engine); needs >= N "
                         "visible devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--replicate", default=None, metavar="SHARD:R",
                    help="[deprecated: use --partition replicate=...] knn "
                         "sharded: replicate shard SHARD onto R extra "
                         "devices and fan its queries across the replica "
                         "set; 'auto:R' picks the hottest shard from a "
                         "sliding query histogram after a short warmup")
    ap.add_argument("--hot-shard", type=int, default=0,
                    help="knn sharded: which shard --hot-frac concentrates "
                         "queries into (default 0)")
    ap.add_argument("--hot-frac", type=float, default=0.0,
                    help="knn sharded: fraction of each query batch drawn "
                         "from the hot shard's vertex range (skewed-city "
                         "traffic; 0 = uniform)")
    ap.add_argument("--hot-flip-round", type=int, default=0, metavar="ROUND",
                    help="knn sharded: at round ROUND re-aim --hot-frac "
                         "traffic at another shard's range (the zipf city "
                         "moving mid-run; exercises the ranges=auto drift "
                         "detector's second re-split)")
    ap.add_argument("--hot-shard2", type=int, default=None,
                    help="knn sharded: the shard --hot-flip-round re-aims "
                         "traffic at (default: the shard opposite "
                         "--hot-shard)")
    ap.add_argument("--rebalance-ratio", type=float, default=1.25,
                    help="knn ranges=auto: re-split when the sliding "
                         "window's balance ratio (hottest shard share x S, "
                         "1.0 = balanced) exceeds this")
    ap.add_argument("--rebalance-window", type=int, default=16,
                    help="knn ranges=auto: rounds of per-vertex query "
                         "history the drift detector slides over")
    ap.add_argument("--rebalance-cooldown", type=int, default=4,
                    help="knn ranges=auto: minimum rounds between re-splits")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory "
                         "(REPRO_COMPILE_CACHE env var is the fallback); a "
                         "second process over the same dir skips cold "
                         "compiles")
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()

    from repro.analysis import sanitize

    # must run before anything compiles: the cache dir only helps programs
    # compiled after it is configured
    sanitize.enable_compile_cache(args.compile_cache)

    arch = get_arch(args.arch)
    if arch.family == "lm":
        args.batch = 4 if args.batch is None else args.batch
        return serve_lm(args)
    if arch.family == "knn":
        return serve_knn(args)
    raise SystemExit(
        f"serve.py drives 'lm' and 'knn' arch families; {args.arch!r} is "
        f"{arch.family!r} (use the train/dryrun drivers for it)"
    )


if __name__ == "__main__":
    main()
