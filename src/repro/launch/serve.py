"""LM serving driver: batched prefill + autoregressive decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --prompt-len 32 --gen 16 --batch 4

Runs the same prefill/decode steps the dry-run lowers for the
prefill_32k/decode_32k cells (GQA grouped-einsum attention, sharded KV
cache); on the CPU container use --smoke.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("serve.py drives LM archs; use knn_build.py for the index")
    cfg = arch.make_smoke() if args.smoke else arch.make_config()
    mesh = make_host_mesh(data=len(jax.devices()))
    rules = make_rules(mesh)

    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    prefill = jax.jit(lambda p, t: tr.prefill(p, t, cfg, max_len, rules))
    decode = jax.jit(lambda p, c, t: tr.decode_step(p, c, t, cfg, rules),
                     donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for step in range(args.gen - 1):
        logits, cache = decode(params, cache, tokens)
        if args.temperature > 0:
            key = jax.random.PRNGKey(100 + step)
            tokens = jax.random.categorical(key, logits / args.temperature, -1).astype(jnp.int32)
        else:
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"model {cfg.name}: prefill({args.batch}x{args.prompt_len}) "
          f"{t_prefill * 1e3:.1f} ms; decode {args.gen - 1} steps "
          f"{t_decode * 1e3:.1f} ms ({tps:.1f} tok/s)")
    print("generated token ids (first sequence):", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
