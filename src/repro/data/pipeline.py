"""Deterministic synthetic data pipelines, one per family.

Every pipeline is seeded and step-indexed: batch(step) is a pure function, so
(a) restarts resume bit-identically from the checkpointed step (fault
tolerance), and (b) straggler-skip barriers can drop a step fleet-wide without
coordination (see distributed/straggler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1), dtype=np.int64)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass(frozen=True)
class MarkovLMStream:
    """First-order Markov token stream — learnable signal for the end-to-end
    training examples (loss provably decreases toward the chain's entropy)."""

    vocab: int
    batch: int
    seq: int
    branching: int = 4  # successors per token; entropy = log(branching)
    seed: int = 0

    def _table(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, self.vocab, size=(self.vocab, self.branching))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        table = self._table()
        toks = np.empty((self.batch, self.seq + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        choices = rng.integers(0, self.branching, size=(self.batch, self.seq))
        for t in range(self.seq):
            toks[:, t + 1] = table[toks[:, t], choices[:, t]]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass(frozen=True)
class RecsysStream:
    n_sparse: int
    bag: int
    rows: int
    batch: int
    multi_hot_fields: int = 4
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        ids = rng.integers(0, self.rows, size=(self.batch, self.n_sparse, self.bag))
        # single-hot fields: only slot 0 valid
        ids[:, self.multi_hot_fields:, 1:] = -1
        labels = rng.integers(0, 2, size=(self.batch,))
        return {"sparse_ids": ids.astype(np.int32), "labels": labels.astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class GraphStream:
    """Batched small graphs (the `molecule` regime) with positions/species."""

    n_nodes: int
    n_edges: int
    batch: int
    n_species: int = 16
    d_feat: int = 0
    n_classes: int = 4
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        n, e, b = self.n_nodes, self.n_edges, self.batch
        src = rng.integers(0, n, size=(b, e // 2))
        dst = rng.integers(0, n, size=(b, e // 2))
        offs = (np.arange(b) * n)[:, None]
        s = np.concatenate([(src + offs).ravel(), (dst + offs).ravel()])
        d = np.concatenate([(dst + offs).ravel(), (src + offs).ravel()])
        batch = {
            "edge_index": np.stack([s, d]).astype(np.int32),
            "pos": rng.standard_normal((b * n, 3)).astype(np.float32) * 2.0,
            "graph_id": np.repeat(np.arange(b), n).astype(np.int32),
            "graph_targets": rng.standard_normal(b).astype(np.float32),
            "labels": rng.integers(0, self.n_classes, size=b * n).astype(np.int32),
        }
        if self.d_feat:
            batch["node_feat"] = rng.standard_normal((b * n, self.d_feat)).astype(np.float32)
        else:
            batch["species"] = rng.integers(0, self.n_species, size=b * n).astype(np.int32)
        return batch


@dataclasses.dataclass(frozen=True)
class FullGraphStream:
    """Fixed full-batch citation-style graph with synthetic labels."""

    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed)  # fixed graph, step-independent
        src = rng.integers(0, self.n_nodes, size=self.n_edges // 2)
        dst = rng.integers(0, self.n_nodes, size=self.n_edges // 2)
        return {
            "edge_index": np.stack(
                [np.concatenate([src, dst]), np.concatenate([dst, src])]
            ).astype(np.int32),
            "node_feat": rng.standard_normal((self.n_nodes, self.d_feat)).astype(np.float32),
            "pos": rng.standard_normal((self.n_nodes, 3)).astype(np.float32),
            "labels": rng.integers(0, self.n_classes, size=self.n_nodes).astype(np.int32),
        }
