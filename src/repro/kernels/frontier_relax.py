"""Fused Pallas TPU kernel for one batched checkIns frontier round.

The batched insert frontier (Algorithm 4's checkIns search, run for a whole
staged batch of inserted objects at once) keeps a multi-source tentative
distance matrix ``dist`` of shape (n+1, B) on device — row v holds, per
source column i, the best known pruned distance from inserted object
``src[i]`` to vertex v. One round relaxes every *receiver* row v (a BNS
neighbor of last round's changed vertices) against its bridge neighbors:

    new[v, i] = min(dist[v, i],
                    min over u in BNS(v), gate(u, i) of  w(v, u) + dist[u, i])
    gate(u, i) = dist[u, i] < kth[u]  or  u == src[i]        (checkIns)

The XLA form (kernels/ops.py) runs a fori_loop over the neighbor columns to
avoid the (R, T, B) candidate tensor; this kernel fuses the whole round the
same way sweep_merge fuses a construction step: the neighbor table ``nbr``
(R, T) and receiver rows (R,) are scalar-prefetched, the grid is (R, T), and
each grid step DMAs exactly one (1, B) neighbor distance row (plus its kth
scalar) into VMEM, accumulating the running minimum in a VMEM scratch row.
At the last neighbor column the accumulator is scattered back into the
aliased ``dist`` output via the receiver-row index map.

Jacobi discipline: receiver rows frequently neighbor each other, so neighbor
distance rows are read from a separate, NON-aliased ``dist`` operand — reads
always see the pre-round values even though receiver rows are being written
in place through the aliased operand (XLA copies the donated buffer when the
read operand still needs the old value). That keeps the kernel bit-identical
to the pure-Jacobi reference for any receiver set, which the exactness
contract of the engine (scalar vs sharded table equality) relies on.

Padded receiver rows use vertex id n (the dummy row: all-pad neighbors, +inf
distances — the round writes +inf back). Padded neighbor slots use -1 with
+inf weight and are clamped to the dummy row by the index map; padded source
columns use src = -1 (matching no vertex) with all-+inf distance columns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _frontier_relax_kernel(
    nbr_ref, rows_ref,                   # scalar-prefetch
    w_ref, kth_ref, src_ref, dn_ref, do_ref,
    out_ref,
    acc_ref,                             # VMEM (1, B) running-minimum scratch
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = do_ref[...]       # receiver's own pre-round row

    u = nbr_ref[i, j]
    nd = dn_ref[...]                     # (1, B) neighbor distance row
    gate = (nd < kth_ref[0, 0]) | (src_ref[...] == u)
    cand = w_ref[0, 0] + nd
    ok = (u >= 0) & gate
    acc_ref[...] = jnp.minimum(acc_ref[...], jnp.where(ok, cand, jnp.inf))

    @pl.when(j == nt - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


def frontier_relax_pallas(
    nbr: jax.Array,   # (R, T) int32 neighbor ids, -1 = padded slot
    rows: jax.Array,  # (R,)  int32 receiver rows, n = padded row (dummy)
    w: jax.Array,     # (R, T) float32 edge weights, +inf on pads
    dist: jax.Array,  # (n+1, B) float32 tentative distances (aliased output)
    kth: jax.Array,   # (n+1,) float32 pruning bounds
    src: jax.Array,   # (B,) int32 source vertex per column, -1 pad
    *,
    interpret: bool = False,
) -> jax.Array:
    """One fused frontier round; returns the updated (n+1, B) dist matrix."""
    chunk, t = nbr.shape
    n1, b = dist.shape
    kth2 = kth.reshape(n1, 1)
    src2 = src.reshape(1, b)

    def nbr_map(i, j, nbr_ref, rows_ref):
        x = nbr_ref[i, j]
        return (jnp.where(x >= 0, x, n1 - 1), 0)  # clamp pads to the dummy row

    def vert_map(i, j, nbr_ref, rows_ref):
        return (rows_ref[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(chunk, t),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, n_, r_: (i, j)),  # w
            pl.BlockSpec((1, 1), nbr_map),                       # kth gather
            pl.BlockSpec((1, b), lambda i, j, n_, r_: (0, 0)),   # src (bcast)
            pl.BlockSpec((1, b), nbr_map),                       # dist read
            pl.BlockSpec((1, b), vert_map),                      # own row read
        ],
        out_specs=pl.BlockSpec((1, b), vert_map),                # dist scatter
        scratch_shapes=[pltpu.VMEM((1, b), jnp.float32)],
    )
    return pl.pallas_call(
        _frontier_relax_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n1, b), jnp.float32),
        # operand indices count the two scalar-prefetch args; only the
        # own-row/scatter operand aliases the output — the neighbor-read
        # operand must keep the pre-round values (see module docstring)
        input_output_aliases={6: 0},
        interpret=interpret,
    )(nbr, rows, w, kth2, src2, dist, dist)
