"""Fused Pallas TPU kernel for one construction sweep step (chunk of a level).

The level-synchronous construction (Algorithm 3, see core/construct_jax.py)
repeats, for every vertex of a level,

    gather the k-lists of its bridge neighbors from the live V_k tables
    -> shift every candidate by the connecting edge weight
    -> merge with the vertex's extra candidates (Lemmas 5.12/5.21)
    -> keep the k closest *distinct* objects
    -> scatter the merged row back into the V_k tables.

The unfused form (seed implementation) ran the gather and shift in XLA,
materialised a (S, T*k + E) candidate tensor in HBM, and handed it to the
`topk_merge` kernel — one full HBM round trip of the candidate tensor per
level. This kernel fuses the whole step: the V_k tables stay in HBM ("ANY"
memory space from the kernel's point of view) and the Pallas pipeline DMAs
exactly the (1, k) rows named by the neighbor table into VMEM, where the
shift, dedup top-k min-selection (k rounds of VPU work over a lane-padded
candidate tile, identical semantics to `topk_merge`) and the scatter of the
result row all happen without ever writing candidates back to HBM.

Mechanics: the neighbor ids `nbr` (CHUNK, T) and target rows `verts` (CHUNK,)
are scalar-prefetched; the grid is (CHUNK, T) and the gather/scatter are
expressed through BlockSpec index maps reading `nbr`/`verts`, so each grid
step pipelines one (1, k) row DMA. The output V_k tables are input/output
aliased: rows not named by `verts` keep their previous values, which is what
makes the kernel a scatter. Correctness of the in-place update relies on the
level schedule invariant that a level only reads rows written by strictly
earlier levels (neighbor rows and target rows are disjoint within a call; the
shared dummy row n is write-garbage and read-masked).

Padded rows use vertex id n (the dummy row) and padded neighbor slots use -1
with +inf weight, exactly as in the XLA path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INT_MAX = jnp.iinfo(jnp.int32).max


def kround_merge(cand_ids: jax.Array, cand_d: jax.Array, k: int):
    """k rounds of dedup min-selection (branch-free, shared by kernel + XLA).

    Semantics match ref.topk_merge_ref: k smallest-distance distinct ids per
    row, distance ties broken by the smaller id, exhausted slots -> (-1, inf).
    cand_d must already be +inf wherever cand_ids < 0.
    """
    b = cand_ids.shape[0]

    def body(i, carry):
        out_ids, out_d, cd = carry
        dmin = jnp.min(cd, axis=1)
        idmin = jnp.min(jnp.where(cd == dmin[:, None], cand_ids, _INT_MAX), axis=1)
        ok = jnp.isfinite(dmin)
        out_ids = jax.lax.dynamic_update_slice(
            out_ids, jnp.where(ok, idmin, -1)[:, None], (0, i))
        out_d = jax.lax.dynamic_update_slice(
            out_d, jnp.where(ok, dmin, jnp.inf)[:, None], (0, i))
        # drop every candidate carrying the selected id -> dedup for free
        cd = jnp.where(cand_ids == idmin[:, None], jnp.inf, cd)
        return out_ids, out_d, cd

    init = (
        jnp.full((b, k), -1, jnp.int32),
        jnp.full((b, k), jnp.inf, jnp.float32),
        cand_d,
    )
    out_ids, out_d, _ = jax.lax.fori_loop(0, k, body, init)
    return out_ids, out_d


def _sweep_merge_kernel(
    nbr_ref, verts_ref,             # scalar-prefetch
    w_ref, exi_ref, exd_ref, vki_ref, vkd_ref,
    oi_ref, od_ref,
    ci_ref, cd_ref,                 # VMEM candidate scratch
    *, k: int, e: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nt = pl.num_programs(1)
    valid = nbr_ref[i, j] >= 0

    @pl.when(j == 0)
    def _init_candidates():
        ci_ref[...] = jnp.full_like(ci_ref, -1)
        cd_ref[...] = jnp.full_like(cd_ref, jnp.inf)
        ex_ids = exi_ref[...]
        ci_ref[:, pl.dslice(nt * k, e)] = ex_ids
        cd_ref[:, pl.dslice(nt * k, e)] = jnp.where(
            ex_ids >= 0, exd_ref[...].astype(jnp.float32), jnp.inf)

    g_ids = vki_ref[...]                                    # gathered (1, k) row
    g_d = w_ref[0, 0] + vkd_ref[...].astype(jnp.float32)
    ok = valid & (g_ids >= 0)
    ci_ref[:, pl.dslice(j * k, k)] = jnp.where(ok, g_ids, -1)
    cd_ref[:, pl.dslice(j * k, k)] = jnp.where(ok, g_d, jnp.inf)

    @pl.when(j == nt - 1)
    def _merge_and_emit():
        out_ids, out_d = kround_merge(ci_ref[...], cd_ref[...], k)
        oi_ref[...] = out_ids
        od_ref[...] = out_d


def sweep_merge_pallas(
    nbr: jax.Array,       # (CHUNK, T) int32, -1 = padded slot
    verts: jax.Array,     # (CHUNK,)  int32, n = padded row (dummy)
    w: jax.Array,         # (CHUNK, T) float32, +inf on padded slots
    ex_ids: jax.Array,    # (n+1, E) int32 per-vertex extra candidates
    ex_d: jax.Array,      # (n+1, E) float32
    vk_ids: jax.Array,    # (n+1, k) int32 live table (aliased to output)
    vk_d: jax.Array,      # (n+1, k) float32 live table (aliased to output)
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One fused construction step; returns the updated (vk_ids, vk_d)."""
    chunk, t = nbr.shape
    e = ex_ids.shape[1]
    n1 = vk_ids.shape[0]
    c_pad = -(-(t * k + e) // 128) * 128  # lane-align the candidate scratch

    def nbr_map(i, j, nbr_ref, verts_ref):
        x = nbr_ref[i, j]
        return (jnp.where(x >= 0, x, n1 - 1), 0)  # clamp pads to the dummy row

    def vert_map(i, j, nbr_ref, verts_ref):
        return (verts_ref[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(chunk, t),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, n_, v_: (i, j)),  # w
            pl.BlockSpec((1, e), vert_map),                      # ex_ids gather
            pl.BlockSpec((1, e), vert_map),                      # ex_d gather
            pl.BlockSpec((1, k), nbr_map),                       # vk_ids gather
            pl.BlockSpec((1, k), nbr_map),                       # vk_d gather
        ],
        out_specs=[
            pl.BlockSpec((1, k), vert_map),                      # vk_ids scatter
            pl.BlockSpec((1, k), vert_map),                      # vk_d scatter
        ],
        scratch_shapes=[
            pltpu.VMEM((1, c_pad), jnp.int32),
            pltpu.VMEM((1, c_pad), jnp.float32),
        ],
    )
    kernel = functools.partial(_sweep_merge_kernel, k=k, e=e)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n1, k), jnp.int32),
            jax.ShapeDtypeStruct((n1, k), jnp.float32),
        ],
        # operand indices count the two scalar-prefetch args
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d)
