"""Pallas TPU kernel: streaming top-k over very wide score rows.

Used for (a) the xdeepfm `retrieval_cand` cell — score 10^6 candidates against
a query and keep the k best — and (b) batched KNN-Index-style nearest-object
queries over dense distance rows. The score row never fits VMEM, so the grid
streams (B_BLK, N_BLK) tiles from HBM and maintains the running top-k in the
revisited output block (sequential innermost grid dimension), merging each
tile with k rounds of vectorised max-selection. One pass over HBM => the op is
memory-bandwidth-bound, which is its roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT_MAX = jnp.iinfo(jnp.int32).max


def _retrieval_topk_kernel(s_ref, oid_ref, od_ref, *, k: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full_like(od_ref, -jnp.inf)
        oid_ref[...] = jnp.full_like(oid_ref, -1)

    s = s_ref[...].astype(jnp.float32)  # (bb, bn)
    gid = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    cd = jnp.concatenate([od_ref[...].astype(jnp.float32), s], axis=1)
    cid = jnp.concatenate([oid_ref[...], gid], axis=1)
    cd = jnp.where(cid < 0, -jnp.inf, cd)

    def body(i, carry):
        out_ids, out_d, rem = carry
        dmax = jnp.max(rem, axis=1)
        idmax = jnp.min(jnp.where(rem == dmax[:, None], cid, _INT_MAX), axis=1)
        valid = jnp.isfinite(dmax)
        sel_id = jnp.where(valid, idmax, -1)
        out_ids = jax.lax.dynamic_update_slice(out_ids, sel_id[:, None], (0, i))
        out_d = jax.lax.dynamic_update_slice(out_d, dmax[:, None], (0, i))
        rem = jnp.where(cid == idmax[:, None], -jnp.inf, rem)
        return out_ids, out_d, rem

    b = s.shape[0]
    init = (
        jnp.full((b, k), -1, jnp.int32),
        jnp.full((b, k), -jnp.inf, jnp.float32),
        cd,
    )
    out_ids, out_d, _ = jax.lax.fori_loop(0, k, body, init)
    oid_ref[...] = out_ids
    od_ref[...] = out_d.astype(od_ref.dtype)


def retrieval_topk_pallas(
    scores: jax.Array,  # (B, N) float; larger = better
    k: int,
    *,
    block_b: int = 8,
    block_n: int = 4096,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, n = scores.shape
    assert b % block_b == 0 and n % block_n == 0
    grid = (b // block_b, n // block_n)  # N innermost: sequential accumulation
    kernel = functools.partial(_retrieval_topk_kernel, k=k, block_n=block_n)
    oid, od = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), scores.dtype),
        ],
        interpret=interpret,
    )(scores)
    return oid, od
