"""Pallas TPU kernel: deduplicating top-k merge of candidate (id, dist) sets.

This is the numeric hot spot of the paper's construction (Lemmas 5.12/5.21):
for every vertex in a level, merge the C = tau*k candidate pairs gathered from
its bridge neighbors' lists and emit the k closest *distinct* objects.

TPU adaptation: a GPU implementation would bitonic-sort the candidates; the
TPU VPU has no efficient in-register sort, so we run k rounds of a vectorised
min-reduction over a VMEM-resident candidate tile, masking out every candidate
that shares the selected id (which performs the dedup for free). O(k*C) VPU
work, branch-free, one HBM read of the candidates and one HBM write of the
result per tile.

Grid: one dimension over vertex blocks. Block shapes: candidates (B_BLK, C) in
VMEM, outputs (B_BLK, k). C is padded to a multiple of 128 (lane width) by the
ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT_MAX = jnp.iinfo(jnp.int32).max


def _topk_merge_kernel(ids_ref, d_ref, oid_ref, od_ref, *, k: int):
    ids = ids_ref[...]
    d = d_ref[...].astype(jnp.float32)
    d = jnp.where(ids < 0, jnp.inf, d)  # padding / invalid candidates

    def body(i, carry):
        out_ids, out_d, cd = carry
        dmin = jnp.min(cd, axis=1)
        # tie-break: smallest id among distance ties
        idmin = jnp.min(jnp.where(cd == dmin[:, None], ids, _INT_MAX), axis=1)
        valid = jnp.isfinite(dmin)
        sel_id = jnp.where(valid, idmin, -1)
        sel_d = jnp.where(valid, dmin, jnp.inf)
        out_ids = jax.lax.dynamic_update_slice(out_ids, sel_id[:, None], (0, i))
        out_d = jax.lax.dynamic_update_slice(out_d, sel_d[:, None], (0, i))
        # mask every candidate carrying the selected id -> dedup
        cd = jnp.where(ids == idmin[:, None], jnp.inf, cd)
        return out_ids, out_d, cd

    b = ids.shape[0]
    init = (
        jnp.full((b, k), -1, jnp.int32),
        jnp.full((b, k), jnp.inf, jnp.float32),
        d,
    )
    out_ids, out_d, _ = jax.lax.fori_loop(0, k, body, init)
    oid_ref[...] = out_ids
    od_ref[...] = out_d.astype(od_ref.dtype)


def topk_merge_pallas(
    cand_ids: jax.Array,  # (B, C) int32, -1 = invalid
    cand_d: jax.Array,    # (B, C) float
    k: int,
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """k nearest distinct-(id) candidates per row; rows padded to block_b."""
    b, c = cand_ids.shape
    assert b % block_b == 0, f"B={b} must be padded to a multiple of {block_b}"
    grid = (b // block_b,)
    kernel = functools.partial(_topk_merge_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), cand_d.dtype),
        ],
        interpret=interpret,
    )(cand_ids, cand_d)
