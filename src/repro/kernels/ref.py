"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_merge_ref(cand_ids: jax.Array, cand_d: jax.Array, k: int):
    """k smallest-distance distinct ids per row; ties broken by smaller id."""
    if cand_ids.shape[1] < k:  # fewer candidate slots than outputs: pad
        pad = ((0, 0), (0, k - cand_ids.shape[1]))
        cand_ids = jnp.pad(cand_ids, pad, constant_values=-1)
        cand_d = jnp.pad(cand_d, pad, constant_values=jnp.inf)
    d = jnp.where(cand_ids < 0, jnp.inf, cand_d.astype(jnp.float32))

    def row(ids_r, d_r):
        order = jnp.lexsort((d_r, ids_r))  # by id, then dist
        sid, sd = ids_r[order], d_r[order]
        first = jnp.concatenate([jnp.ones((1,), bool), sid[1:] != sid[:-1]])
        sd = jnp.where(first, sd, jnp.inf)  # dedup: keep min dist per id
        order2 = jnp.lexsort((sid, sd))  # by dist, then id
        top_ids = sid[order2[:k]]
        top_d = sd[order2[:k]]
        return jnp.where(jnp.isfinite(top_d), top_ids, -1), top_d

    out_ids, out_d = jax.vmap(row)(cand_ids, d)
    return out_ids, out_d.astype(cand_d.dtype)


def sweep_merge_ref(
    nbr: jax.Array,     # (CHUNK, T) int32, -1 = padded slot
    verts: jax.Array,   # (CHUNK,)  int32, n = dummy row
    w: jax.Array,       # (CHUNK, T) float32
    ex_ids: jax.Array,  # (n+1, E) int32
    ex_d: jax.Array,    # (n+1, E) float32
    vk_ids: jax.Array,  # (n+1, k) int32
    vk_d: jax.Array,    # (n+1, k) float32
    k: int,
):
    """Unfused oracle for the sweep_merge kernel: explicit candidate tensor.

    gather neighbor k-lists -> shift by edge weight -> append extras ->
    topk_merge_ref -> scatter rows back into copies of the V_k tables.
    """
    chunk, t = nbr.shape
    n1 = vk_ids.shape[0]
    valid = nbr >= 0
    nbr_c = jnp.where(valid, nbr, n1 - 1)
    g_ids = jnp.where(valid[..., None], vk_ids[nbr_c], -1)
    g_d = w[..., None] + vk_d[nbr_c]
    cand_ids = jnp.concatenate([g_ids.reshape(chunk, t * k), ex_ids[verts]], axis=1)
    cand_d = jnp.concatenate([g_d.reshape(chunk, t * k), ex_d[verts]], axis=1)
    m_ids, m_d = topk_merge_ref(cand_ids, cand_d.astype(jnp.float32), k)
    return vk_ids.at[verts].set(m_ids), vk_d.at[verts].set(m_d)


def frontier_relax_ref(
    nbr: jax.Array,   # (R, T) int32 BNS neighbor ids per receiver row, -1 pad
    rows: jax.Array,  # (R,)  int32 receiver vertex ids, n = dummy pad
    w: jax.Array,     # (R, T) float  BNS edge weights, +inf on pads
    dist: jax.Array,  # (n+1, B) tentative multi-source distance columns
    kth: jax.Array,   # (n+1,) per-vertex k-th-distance pruning bound
    src: jax.Array,   # (B,)  int32 source vertex per column, -1 pad
):
    """Unfused oracle for one batched pruned-relaxation (checkIns) round.

    For every receiver row v and source column i:
        new[v, i] = min(dist[v, i],
                        min over u in BNS(v) with gate(u, i) of
                            w(v, u) + dist[u, i])
    where ``gate(u, i) = dist[u, i] < kth[u]  or  u == src[i]`` — Algorithm
    4's checkIns test: a neighbor u propagates distance mass only while the
    inserted object would enter u's top-k (or u is the source itself). Pure
    Jacobi: every read sees the pre-round ``dist``. Materialises the full
    (R, T, B) candidate tensor; the production forms in kernels/ops.py and
    kernels/frontier_relax.py compute the same values without it.
    """
    n1 = dist.shape[0]
    valid = nbr >= 0
    nc = jnp.where(valid, nbr, n1 - 1)
    nd = dist[nc]                                            # (R, T, B)
    gate = (nd < kth[nc][..., None]) | (nc[..., None] == src[None, None, :])
    cand = jnp.where(valid[..., None] & gate, w[..., None] + nd, jnp.inf)
    acc = jnp.minimum(dist[rows], jnp.min(cand, axis=1))
    return dist.at[rows].set(acc)


def minplus_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    out = jnp.min(af[:, :, None] + bf[None, :, :], axis=1)
    return out.astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool):
    """Dense softmax attention with GQA head repetition (fp32 math)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sc = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    sc = sc / d**0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[1]), bool))
        sc = jnp.where(mask, sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def retrieval_topk_ref(scores: jax.Array, k: int):
    """k largest scores per row with their indices; ties -> smaller index."""
    s = scores.astype(jnp.float32)

    def row(s_r):
        idx = jnp.arange(s_r.shape[0], dtype=jnp.int32)
        order = jnp.lexsort((idx, -s_r))
        return idx[order[:k]], s_r[order[:k]]

    oid, od = jax.vmap(row)(s)
    return oid, od.astype(scores.dtype)
