"""Pallas TPU kernel: flash attention forward (GQA-aware).

The roofline analysis (EXPERIMENTS.md §Perf) shows the pure-jnp chunked
attention is memory-bound: the (q_chunk, kv_chunk) probability blocks
materialise in HBM between fusions — S^2-proportional traffic. This kernel
keeps the running (m, l, acc) statistics in VMEM scratch across the
sequential kv-block grid dimension, so probabilities never leave VMEM: HBM
traffic drops to O(S*D) reads of Q/K/V plus one O(S*D) write of the output.

Grid: (B*H, S/block_q, T/block_k) with the kv dimension innermost
(sequential); KV heads are mapped through the BlockSpec index function, so
GQA never materialises repeated KV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0]                      # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if causal:
        qi = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qi >= ki, s, -jnp.inf)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    finite = jnp.isfinite(m_new)
    p = jnp.where(finite[:, None], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, s_len, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    bq = min(block_q, s_len)
    bk = min(block_k, t)
    assert s_len % bq == 0 and t % bk == 0, (s_len, bq, t, bk)
    nq, nk = s_len // bq, t // bk

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s_len, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)

    def kv_index(bh, i, j):
        return (bh // h) * hkv + (bh % h) // rep, j, 0

    kernel = functools.partial(
        _flash_kernel, scale=d**-0.5, causal=causal, block_q=bq, block_k=bk, nk=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s_len, d).transpose(0, 2, 1, 3)
