"""Pallas TPU kernel: blocked tropical (min,+) matrix multiply.

C[i, j] = min_k (A[i, k] + B[k, j])

This is the TPU-native form of the paper's relaxation steps: Algorithm 1's
edge-deletion pass computes, for each vertex w, new_phi(w, u) =
min_v (phi(w, v) + D[v, u]) over the clique of w's higher-ranked neighbors —
a min-plus mat-vec against the exact-distance clique matrix; batched over a
level it is exactly this GEMM-shaped op. The MXU cannot evaluate the tropical
semiring, so the kernel tiles HBM->VMEM like a matmul but accumulates with
VPU minimum over the K-tile loop (grid dim 2, sequential innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk)
    b = b_ref[...].astype(jnp.float32)  # (bk, bn)

    def body(t, acc):
        row = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=1)  # (bm, 1)
        col = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)  # (1, bn)
        return jnp.minimum(acc, row + col)

    acc = jax.lax.fori_loop(0, bk, body, jnp.full(o_ref.shape, jnp.inf, jnp.float32))
    o_ref[...] = jnp.minimum(o_ref[...], acc.astype(o_ref.dtype))


def minplus_matmul_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0
    grid = (m // block_m, n // block_n, kdim // block_k)
    kernel = functools.partial(_minplus_kernel, bk=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_n), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
