"""Jitted public wrappers around the Pallas kernels.

Each wrapper pads inputs to the kernel's tiling constraints, picks
interpret-mode automatically off-TPU (the container target is TPU v5e; CPU
runs validate the kernel bodies), and falls back to the jnp reference when a
shape is too small to be worth tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.frontier_relax import frontier_relax_pallas
from repro.kernels.minplus import minplus_matmul_pallas
from repro.kernels.retrieval_topk import retrieval_topk_pallas
from repro.kernels.sweep_merge import kround_merge, sweep_merge_pallas
from repro.kernels.topk_merge import topk_merge_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("k", "block_b", "use_pallas", "interpret"))
def topk_merge(
    cand_ids: jax.Array,
    cand_d: jax.Array,
    k: int,
    *,
    block_b: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k distinct-(id) merge. cand_ids: (B, C) int32 (-1 invalid)."""
    if not use_pallas:
        return ref.topk_merge_ref(cand_ids, cand_d, k)
    b = cand_ids.shape[0]
    ids = _pad_to(_pad_to(cand_ids, 1, 128, -1), 0, block_b, -1)
    d = _pad_to(_pad_to(cand_d, 1, 128, jnp.inf), 0, block_b, jnp.inf)
    itp = (not _on_tpu()) if interpret is None else interpret
    oid, od = topk_merge_pallas(ids, d, k, block_b=block_b, interpret=itp)
    return oid[:b], od[:b]


def sweep_merge(
    nbr: jax.Array,
    verts: jax.Array,
    w: jax.Array,
    ex_ids: jax.Array,
    ex_d: jax.Array,
    vk_ids: jax.Array,
    vk_d: jax.Array,
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused construction step: gather + shift + dedup top-k + scatter.

    Updates rows ``verts`` of the live (n+1, k) V_k tables from the k-lists of
    the neighbors in ``nbr`` (shifted by ``w``) merged with per-vertex extras.
    Unlike the other wrappers this is a *trace-level* function, meant to be
    called inside an already-jitted sweep loop (core/construct_jax.py), so it
    does no padding or jit of its own: the caller guarantees the layout
    invariants (padded slots -1/+inf, dummy row n).

    The XLA fallback materialises the (CHUNK, T*k+E) candidate tensor and runs
    the same k-round merge; the Pallas path never materialises it (see
    sweep_merge.py).
    """
    if not use_pallas:
        chunk, t = nbr.shape
        n1 = vk_ids.shape[0]
        valid = nbr >= 0
        nbr_c = jnp.where(valid, nbr, n1 - 1)
        g_ids = jnp.where(valid[..., None], vk_ids[nbr_c], -1)
        g_d = w[..., None] + vk_d[nbr_c]
        cand_ids = jnp.concatenate([g_ids.reshape(chunk, t * k), ex_ids[verts]], axis=1)
        cand_d = jnp.concatenate(
            [g_d.reshape(chunk, t * k), ex_d[verts]], axis=1
        ).astype(jnp.float32)
        cand_d = jnp.where(cand_ids < 0, jnp.inf, cand_d)
        m_ids, m_d = kround_merge(cand_ids, cand_d, k)
        return vk_ids.at[verts].set(m_ids), vk_d.at[verts].set(m_d)
    itp = (not _on_tpu()) if interpret is None else interpret
    return sweep_merge_pallas(
        nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d, k=k, interpret=itp
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "use_pallas", "interpret"))
def minplus_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Tropical (min,+) matmul C = A (+,min) B."""
    if not use_pallas:
        return ref.minplus_matmul_ref(a, b)
    m, kdim = a.shape
    _, n = b.shape
    ap = _pad_to(_pad_to(a, 0, block_m, jnp.inf), 1, block_k, jnp.inf)
    bp = _pad_to(_pad_to(b, 0, block_k, jnp.inf), 1, block_n, jnp.inf)
    itp = (not _on_tpu()) if interpret is None else interpret
    out = minplus_matmul_pallas(
        ap, bp, block_m=block_m, block_n=block_n, block_k=block_k, interpret=itp
    )
    return out[:m, :n]


@jax.jit
def serve_gather(
    vk_ids: jax.Array,   # (n+1, k) int32 live index table (dummy row last)
    vk_d: jax.Array,     # (n+1, k) float32
    queries: jax.Array,  # (B,) int32 query vertices
    ks: jax.Array,       # (B,) int32 per-query result count, <= k
) -> tuple[jax.Array, jax.Array]:
    """Batched kNN query: one row gather + per-query k mask (Theorem 4.3).

    Columns at positions >= ks[b] are masked to the pad sentinel (-1, +inf),
    so one (B, k) launch serves heterogeneous-k traffic.
    """
    ids = vk_ids[queries]
    d = vk_d[queries]
    b, k = ids.shape
    mask = jax.lax.broadcasted_iota(jnp.int32, (b, k), 1) < ks[:, None]
    return jnp.where(mask, ids, -1), jnp.where(mask & (ids >= 0), d, jnp.inf)


@jax.jit
def rows_containing(vk_ids: jax.Array, obj_ids: jax.Array) -> jax.Array:
    """(n,) bool: which index rows hold any of ``obj_ids`` (dummy row excluded).

    The vectorized replacement for the host checkDel membership scan: the
    rows a batched delete must repair are exactly the rows naming a deleted
    object, and this finds them in one device pass over the table.
    """
    return (vk_ids[:-1, :, None] == obj_ids[None, None, :]).any(axis=(1, 2))


def frontier_relax(
    nbr: jax.Array,   # (R, T) int32 BNS neighbor ids per receiver, -1 pad
    rows: jax.Array,  # (R,)  int32 receiver rows, n (dummy) = padding
    w: jax.Array,     # (R, T) float32 BNS edge weights, +inf on pads
    dist: jax.Array,  # (n+1, B) float32 multi-source tentative distances
    kth: jax.Array,   # (n+1,) float32 k-th-distance pruning bounds
    src: jax.Array,   # (B,) int32 source vertex per column, -1 pad
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """One batched pruned-relaxation round of the checkIns frontier.

    Relaxes every receiver row's BNS edges for a whole batch of insert
    sources at once: column i of ``dist`` is the tentative distance field of
    source ``src[i]``, and a neighbor u only propagates into column i while
    ``dist[u, i] < kth[u]`` (Algorithm 4's checkIns test — the insertion
    still improves u's top-k) or u is the source itself. Returns the updated
    ``dist``; the caller derives the changed-row mask that narrows the next
    round's frontier (the same discipline the delete-repair rounds use).

    Like ``sweep_merge`` this is a trace-level function meant to be called
    inside an already-jitted round program; the caller guarantees the layout
    invariants (pad conventions above, dummy row n all +inf). The XLA form
    runs a fori_loop over neighbor columns so only (R, B) intermediates ever
    materialise; the Pallas kernel fuses the gather/gate/min per neighbor
    row (see kernels/frontier_relax.py). Both are pure Jacobi: every
    neighbor read sees the pre-round ``dist``.
    """
    if not use_pallas:
        n1 = dist.shape[0]

        def body(t, acc):
            nv = jax.lax.dynamic_index_in_dim(nbr, t, axis=1, keepdims=False)
            wv = jax.lax.dynamic_index_in_dim(w, t, axis=1, keepdims=False)
            valid = nv >= 0
            nc = jnp.where(valid, nv, n1 - 1)
            nd = dist[nc]                                        # (R, B)
            gate = (nd < kth[nc][:, None]) | (nc[:, None] == src[None, :])
            cand = wv[:, None] + nd
            ok = valid[:, None] & gate
            return jnp.minimum(acc, jnp.where(ok, cand, jnp.inf))

        acc = jax.lax.fori_loop(0, nbr.shape[1], body, dist[rows])
        return dist.at[rows].set(acc)
    itp = (not _on_tpu()) if interpret is None else interpret
    return frontier_relax_pallas(nbr, rows, w, dist, kth, src, interpret=itp)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def rows_merge(
    vk_ids: jax.Array,    # (n+1, k) int32 live table
    vk_d: jax.Array,      # (n+1, k) float32
    rows: jax.Array,      # (R,) int32 target rows, n (dummy) = padding
    cand_ids: jax.Array,  # (R, P) int32 new candidates per row, -1 = padding
    cand_d: jax.Array,    # (R, P) float32
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched row repair: merge per-row candidates into the live tables.

    Gathers the ``rows`` out of the table, appends ``cand_*``, reruns the
    dedup top-k merge (the construction kernel) and scatters the results
    back — the device form of Algorithm 4 lines 9-10 over a whole batch.
    """
    own_ids = vk_ids[rows]
    own_d = vk_d[rows]
    cat_ids = jnp.concatenate([own_ids, cand_ids], axis=1)
    cat_d = jnp.concatenate([own_d, cand_d.astype(vk_d.dtype)], axis=1)
    cat_d = jnp.where(cat_ids < 0, jnp.inf, cat_d)
    m_ids, m_d = topk_merge(cat_ids, cat_d, k, use_pallas=use_pallas, interpret=interpret)
    return vk_ids.at[rows].set(m_ids), vk_d.at[rows].set(m_d)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def rows_purge_merge(
    vk_ids: jax.Array,    # (n+1, k) int32 live table
    vk_d: jax.Array,      # (n+1, k) float32
    rows: jax.Array,      # (R,) int32 target rows, n (dummy) = padding
    del_ids: jax.Array,   # (D,) int32 deleted object ids, n = padding
    cand_ids: jax.Array,  # (R, P) int32 new candidates per row, -1 = padding
    cand_d: jax.Array,    # (R, P) float32
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused batched move repair: purge + candidate merge in ONE pass.

    The device form of a coalesced *move* flush (Algorithms 4+5 combined):
    each row is gathered once, its entries naming a deleted object become pad
    sentinels, the surviving entries and the new insert candidates run through
    one dedup top-k merge, and the row scatters back — instead of a purge
    gather/merge/scatter followed by a separate insert gather/merge/scatter
    over largely the same rows. ``rows`` is the union of the delete-hit rows
    and the insert (checkIns) frontier; rows outside one of the two sets just
    carry all-pad columns for the other.
    """
    own_ids = vk_ids[rows]
    own_d = vk_d[rows]
    hit = (own_ids[:, :, None] == del_ids[None, None, :]).any(axis=-1)
    pid = jnp.where(hit, -1, own_ids)
    pd = jnp.where(hit, jnp.inf, own_d)
    cat_ids = jnp.concatenate([pid, cand_ids], axis=1)
    cat_d = jnp.concatenate([pd, cand_d.astype(vk_d.dtype)], axis=1)
    cat_d = jnp.where(cat_ids < 0, jnp.inf, cat_d)
    m_ids, m_d = topk_merge(cat_ids, cat_d, k, use_pallas=use_pallas, interpret=interpret)
    return vk_ids.at[rows].set(m_ids), vk_d.at[rows].set(m_d)


# ----------------------------------------------------------------------
# Shard-local variants (for use inside ``shard_map`` blocks).
#
# The sharded engine (core/sharded.py) stores the (n+1, k) tables row-sharded
# across a 1-D mesh: shard ``s`` owns the contiguous vertex range
# [s*R, (s+1)*R) as a local (R+1, k) block whose last row is that shard's own
# dummy gather row. These variants are trace-level functions called from
# inside a ``shard_map`` body: they take the shard's *global* row ids plus the
# shard's ``row_offset`` (= s*R) and localize on device, so the host routes
# work by owner without rewriting indices per shard. Padded slots use global
# row id -1 (-> the local dummy row).
# ----------------------------------------------------------------------


def shard_local_rows(block_rows: int, rows: jax.Array, row_offset) -> jax.Array:
    """Global row ids -> local block rows; -1 (padding) -> the local dummy."""
    return jnp.where(rows < 0, block_rows - 1, rows - row_offset)


def shard_gather_rows(
    vk_ids: jax.Array,   # (R+1, k) int32 shard-local table block (dummy row last)
    vk_d: jax.Array,     # (R+1, k) float32
    rows: jax.Array,     # (B,) int32 GLOBAL row ids owned by this shard, -1 pad
    row_offset,          # scalar int32: first global row owned by this shard
) -> tuple[jax.Array, jax.Array]:
    """Shard-local ``serve_gather``: one row gather out of this shard's block.

    Padded query slots (-1) read the shard's dummy row and come back as the
    pad sentinel (-1, +inf); the caller drops them when reassembling the
    per-shard result tiles into the original batch order.
    """
    loc = shard_local_rows(vk_ids.shape[0], rows, row_offset)
    return vk_ids[loc], vk_d[loc]


def shard_rows_containing(
    vk_ids: jax.Array,   # (R+1, k) int32 shard-local table block
    obj_ids: jax.Array,  # (D,) int32 deleted object ids (global, replicated)
) -> jax.Array:
    """(R,) bool: which of this shard's rows hold any of ``obj_ids``.

    The per-shard half of ``rows_containing``: each shard scans only its own
    block and the host concatenates the per-shard hit masks back into global
    vertex ids (rows past n in the last shard are all-pad, so never hit).
    """
    return (vk_ids[:-1, :, None] == obj_ids[None, None, :]).any(axis=(1, 2))


def shard_rows_purge_merge(
    vk_ids: jax.Array,    # (R+1, k) int32 shard-local table block
    vk_d: jax.Array,      # (R+1, k) float32
    rows: jax.Array,      # (B,) int32 GLOBAL row ids owned by this shard, -1 pad
    row_offset,           # scalar int32: first global row owned by this shard
    del_ids: jax.Array,   # (D,) int32 deleted object ids (global, replicated)
    cand_ids: jax.Array,  # (B, P) int32 new candidates per row, -1 = padding
    cand_d: jax.Array,    # (B, P) float32
    k: int,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shard-local ``rows_purge_merge`` + per-row changed mask.

    Identical math to the global op (gather own rows, drop deleted entries,
    merge candidates, recompact, scatter back into the block) over this
    shard's slice of the row batch; additionally returns the (B,) changed
    mask the repair rounds use to narrow the next round's frontier, so one
    op serves both the flush's purge+merge pass and each Jacobi repair round.
    Object ids in the table are global vertex ids, so the purge membership
    test needs no localization — only the row indices do.
    """
    loc = shard_local_rows(vk_ids.shape[0], rows, row_offset)
    own_ids = vk_ids[loc]
    own_d = vk_d[loc]
    hit = (own_ids[:, :, None] == del_ids[None, None, :]).any(axis=-1)
    pid = jnp.where(hit, -1, own_ids)
    pd = jnp.where(hit, jnp.inf, own_d)
    cat_ids = jnp.concatenate([pid, cand_ids], axis=1)
    cat_d = jnp.concatenate([pd, cand_d.astype(vk_d.dtype)], axis=1)
    cat_d = jnp.where(cat_ids < 0, jnp.inf, cat_d)
    m_ids, m_d = topk_merge(cat_ids, cat_d, k, use_pallas=use_pallas, interpret=interpret)
    changed = jnp.any((m_ids != own_ids) | (m_d != own_d), axis=1)
    return vk_ids.at[loc].set(m_ids), vk_d.at[loc].set(m_d), changed


# ----------------------------------------------------------------------
# Collective-halo building blocks. The sharded engine's all_gather halo
# programs (sharded._device_fns: "expand" / "rhalo" / "fhalo") are thin
# shard_map shells around these trace-level pieces, so the candidate
# construction stays bit-identical to the host-routed halo (same neighbor-
# major column order, same pad-sentinel semantics) and unit-testable
# outside a mesh.
# ----------------------------------------------------------------------

_I32_SENTINEL = 2**31 - 1  # sorts past every valid vertex id


def masked_unique(x: jax.Array) -> jax.Array:
    """Sorted unique of the non-negative entries of ``x``, -1 padded.

    Fixed-shape (same length as the input) device dedup: invalid entries
    (< 0) map to an int32 sentinel that sorts last, a sort groups
    duplicates, the first-of-run mask keeps one representative, and a
    second sort compacts the survivors to the front. The output is the
    ascending unique set followed by -1 pads — exactly ``np.unique`` of
    the valid entries, which is what pins the device receiver-set
    expansion to the host set-algebra oracle.
    """
    s = jnp.sort(jnp.where(x < 0, _I32_SENTINEL, x).astype(jnp.int32).ravel())
    first = jnp.concatenate([jnp.ones(1, bool), s[1:] != s[:-1]])
    keep = first & (s < _I32_SENTINEL)
    compact = jnp.sort(jnp.where(keep, s, _I32_SENTINEL))
    return jnp.where(compact == _I32_SENTINEL, -1, compact)


def halo_candidates(
    recv_ids: jax.Array,  # (M, k) int32 received neighbor rows
    recv_d: jax.Array,    # (M, k) float32
    slot: jax.Array,      # (B, t) int32 recv-buffer row per neighbor (M = miss)
    w: jax.Array,         # (B, t) float32 edge weights (pad value irrelevant)
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Received halo rows -> per-receiver (B, t*k) repair candidates.

    The same shift-and-flatten the host-routed repair performs on numpy
    (``_repair_part``): candidate order is neighbor-major / table-column-
    minor, pad entries (id < 0, including every miss slot — ``slot == M``
    reads clamp to the last row and the miss mask forces id -1) carry +inf
    distances. float32 add on device == float32 add on host, so the
    merged tables stay bit-identical across halo modes.
    """
    b, t = slot.shape
    m = recv_ids.shape[0]
    safe = jnp.minimum(slot, m - 1)
    g_ids = jnp.where((slot < m)[..., None], recv_ids[safe], -1)  # (B, t, k)
    g_d = w[..., None] + recv_d[safe]
    cand_ids = g_ids.reshape(b, t * k)
    cand_d = jnp.where(cand_ids < 0, jnp.inf, g_d.reshape(b, t * k))
    return cand_ids, cand_d.astype(jnp.float32)


def halo_fold_min(
    recv: jax.Array,  # (M, B) float32 received gated send rows
    slot: jax.Array,  # (R, t) int32 recv-buffer row per neighbor (M = miss)
    w: jax.Array,     # (R, t) float32 edge weights
) -> jax.Array:
    """Received frontier send rows -> per-receiver (R, B) min-folded cand.

    One neighbor column at a time — (R, B) intermediates, never the
    (R, t, B) tensor — mirroring both ``ops.frontier_relax``'s fori_loop
    form and the host-routed fold in ``_frontier_part``. Miss slots
    (``slot == M``) clamp their gather to the last row and are masked to
    +inf, so no sentinel row is ever materialized; min is fold-order-
    insensitive, so the distance trajectories stay bit-identical.
    """
    t = slot.shape[1]
    m = recv.shape[0]

    def body(j, cand):
        sl = slot[:, j]
        row = w[:, j, None] + recv[jnp.minimum(sl, m - 1)]
        return jnp.minimum(cand, jnp.where((sl < m)[:, None], row, jnp.inf))

    init = jnp.full((slot.shape[0], recv.shape[1]), jnp.inf, jnp.float32)
    return jax.lax.fori_loop(0, t, body, init)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def rows_purge(
    vk_ids: jax.Array,   # (n+1, k) int32 live table
    vk_d: jax.Array,     # (n+1, k) float32
    rows: jax.Array,     # (R,) int32 rows to purge, n (dummy) = padding
    del_ids: jax.Array,  # (D,) int32 deleted object ids
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched row purge: drop ``del_ids`` entries and recompact the rows.

    Deleted entries become pad sentinels and the top-k merge re-sorts them to
    the row tail (Algorithm 5's removal phase, vectorized over the batch).
    """
    own_ids = vk_ids[rows]
    own_d = vk_d[rows]
    hit = (own_ids[:, :, None] == del_ids[None, None, :]).any(axis=-1)
    pid = jnp.where(hit, -1, own_ids)
    pd = jnp.where(hit, jnp.inf, own_d)
    m_ids, m_d = topk_merge(pid, pd, k, use_pallas=use_pallas, interpret=interpret)
    return vk_ids.at[rows].set(m_ids), vk_d.at[rows].set(m_d)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "use_pallas", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused attention; q (B,S,H,D), kv (B,T,Hkv,D) -> (B,S,H,D)."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    itp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=itp
    )


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_n", "use_pallas", "interpret"))
def retrieval_topk(
    scores: jax.Array,
    k: int,
    *,
    block_b: int = 8,
    block_n: int = 4096,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Streaming top-k (largest) over (B, N) score rows."""
    if not use_pallas:
        return ref.retrieval_topk_ref(scores, k)
    b, n = scores.shape
    bn = min(block_n, max(128, n))
    sp = _pad_to(_pad_to(scores, 0, block_b, -jnp.inf), 1, bn, -jnp.inf)
    itp = (not _on_tpu()) if interpret is None else interpret
    oid, od = retrieval_topk_pallas(sp, k, block_b=block_b, block_n=bn, interpret=itp)
    return oid[:b], od[:b]
