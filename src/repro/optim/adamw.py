"""AdamW with fp32 moments, global-norm clipping and cosine schedule.

Functional, pytree-shaped like the params; moment shardings mirror the param
shardings (FSDP: optimizer state is fully sharded with the params).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs) -> dict:
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step_ = lr * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step_ = step_ + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm
