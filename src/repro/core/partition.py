"""Unified partition-layout surface: ``PartitionPlan`` + the range splitter.

The partition configuration used to be scattered across flags and kwargs —
``--shards N`` / ``--replicate SHARD:R|auto:R`` on serve.py,
``build_sharded_engine(..., shards=, replication=)`` and
``load_engine(..., shards=, replication=)`` on the facade — and ISSUE 9 adds
a fourth axis (uneven range boundaries). ``PartitionPlan`` is the one value
object all of them construct and every layout-accepting entry point takes:

    plan = PartitionPlan.parse("shards=4,replicate=auto:2,ranges=auto")
    engine = knn.build_sharded_engine(bn, objects, k, plan=plan)

* ``shards`` — shard count (None = every visible device).
* ``ranges`` — ``None`` (equal-width), ``"auto"`` (histogram-driven: object
  density at build time, the sliding query histogram in serve.py), or an
  explicit tuple of sorted start boundaries, one per shard, first 0.
* ``replication`` — ``None``, an ``("auto", R)`` marker (serve.py's hottest
  shard watcher picks the shard), or normalized ``((shard, extras), ...)``
  pairs. ``()`` force-drops a plan an artifact saved.
* ``policy`` — replica routing policy (``round_robin`` /
  ``least_outstanding``).

The old flags/kwargs remain as thin deprecation shims that construct a plan
(``PartitionPlan.resolve`` is that shim's single merge point); mixing a
plan with the legacy kwargs is an ``EngineConfigError``, not a silent
override.

``propose_starts`` is the histogram-driven splitter: cumulative-weight
quantile cuts over a per-vertex weight vector (query counts, object
density), strictly-increasing boundaries enforced, so every shard gets a
non-empty range whose weight share is as close to ``1/shards`` as the
histogram allows.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.errors import EngineConfigError

ROUTE_POLICIES = ("round_robin", "least_outstanding")

_SPEC_KEYS = ("shards", "replicate", "ranges", "policy")


@dataclass(frozen=True)
class PartitionPlan:
    """One value object for the whole partition layout (see module doc)."""

    shards: int | None = None
    ranges: tuple[int, ...] | str | None = None
    replication: tuple | None = None
    policy: str = "round_robin"

    def __post_init__(self):
        if self.shards is not None:
            if not isinstance(self.shards, (int, np.integer)) or int(self.shards) < 1:
                raise EngineConfigError(
                    f"PartitionPlan.shards must be a positive int or None, "
                    f"got {self.shards!r}"
                )
            object.__setattr__(self, "shards", int(self.shards))
        object.__setattr__(self, "ranges", self._norm_ranges(self.ranges))
        object.__setattr__(self, "replication", self._norm_replication(self.replication))
        if self.policy not in ROUTE_POLICIES:
            raise EngineConfigError(
                f"unknown replica routing policy {self.policy!r} "
                f"(want one of {ROUTE_POLICIES})"
            )
        if isinstance(self.ranges, tuple):
            if self.shards is None:
                object.__setattr__(self, "shards", len(self.ranges))
            elif self.shards != len(self.ranges):
                raise EngineConfigError(
                    f"PartitionPlan names {self.shards} shards but "
                    f"{len(self.ranges)} range boundaries"
                )

    def _norm_ranges(self, ranges):
        if ranges is None or ranges == "auto":
            return ranges
        if ranges == "equal":
            return None
        if isinstance(ranges, str):
            raise EngineConfigError(
                f"PartitionPlan.ranges must be None, 'auto', 'equal' or a "
                f"tuple of start boundaries, got {ranges!r}"
            )
        starts = tuple(int(s) for s in ranges)
        if not starts or starts[0] != 0:
            raise EngineConfigError(
                f"range boundaries must start at vertex 0, got {starts!r}"
            )
        if any(b <= a for a, b in zip(starts, starts[1:])):
            raise EngineConfigError(
                f"range boundaries must be strictly increasing, got {starts!r}"
            )
        return starts

    def _norm_replication(self, rep):
        if rep is None:
            return None
        if isinstance(rep, tuple) and len(rep) == 2 and rep[0] == "auto":
            extras = int(rep[1])
            if extras < 1:
                raise EngineConfigError(
                    f"auto-replication count must be >= 1, got {extras}"
                )
            return ("auto", extras)
        if isinstance(rep, dict):
            rep = sorted(rep.items())
        pairs = []
        for item in rep:
            s, r = item
            s, r = int(s), int(r)
            if s < 0:
                raise EngineConfigError(f"replication names negative shard {s}")
            if r < 0:
                raise EngineConfigError(
                    f"replica count for shard {s} must be >= 0, got {r}"
                )
            pairs.append((s, r))
        return tuple(sorted(pairs))

    # -- construction shims ---------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "PartitionPlan":
        """Parse a ``--partition`` SPEC string, e.g.
        ``shards=4,replicate=auto:2,ranges=auto`` or
        ``shards=3,ranges=0:100:700,policy=least_outstanding``."""
        kw: dict = {}
        for field in filter(None, (f.strip() for f in str(spec).split(","))):
            if "=" not in field:
                raise EngineConfigError(
                    f"partition spec field {field!r} is not key=value "
                    f"(keys: {', '.join(_SPEC_KEYS)})"
                )
            key, val = (p.strip() for p in field.split("=", 1))
            if key not in _SPEC_KEYS:
                raise EngineConfigError(
                    f"unknown partition spec key {key!r} "
                    f"(keys: {', '.join(_SPEC_KEYS)})"
                )
            if key in kw:
                raise EngineConfigError(f"duplicate partition spec key {key!r}")
            try:
                if key == "shards":
                    kw["shards"] = int(val)
                elif key == "policy":
                    kw["policy"] = val
                elif key == "ranges":
                    kw["ranges"] = (
                        val if val in ("auto", "equal")
                        else tuple(int(b) for b in val.split(":"))
                    )
                else:  # replicate=auto:R | SHARD:R
                    shard, extras = val.split(":", 1)
                    kw["replication"] = (
                        ("auto", int(extras)) if shard == "auto"
                        else ((int(shard), int(extras)),)
                    )
            except EngineConfigError:
                raise
            except ValueError as e:
                raise EngineConfigError(
                    f"cannot parse partition spec field {field!r}: {e}"
                ) from None
        return cls(**kw)

    @classmethod
    def resolve(
        cls,
        plan: "PartitionPlan | str | None",
        *,
        shards: int | None = None,
        replication=None,
        policy: str | None = None,
    ) -> "PartitionPlan":
        """Merge point for the legacy kwargs: either a plan OR the old
        ``shards=``/``replication=`` kwargs, never both."""
        if isinstance(plan, str):
            plan = cls.parse(plan)
        if plan is not None:
            if shards is not None or replication is not None or policy is not None:
                raise EngineConfigError(
                    "pass either plan= or the legacy shards=/replication= "
                    "kwargs, not both"
                )
            return plan
        rep = None
        if replication is not None:
            # legacy {} means "force-drop a saved plan": keep it distinct
            # from None (= no opinion) as the empty pair tuple
            rep = tuple(sorted((int(s), int(r)) for s, r in replication.items()))
        return cls(
            shards=shards, replication=rep,
            policy="round_robin" if policy is None else policy,
        )

    # -- consumers -------------------------------------------------------

    def replication_dict(self) -> dict[int, int] | None:
        """The explicit shard -> extras plan, ``{}`` for a force-drop, or
        None when unset / deferred to the ``auto`` watcher."""
        if self.replication is None or self.auto_replicas():
            return None
        return {s: r for s, r in self.replication}

    def auto_replicas(self) -> int:
        """Replica count of an ``("auto", R)`` marker, else 0."""
        if (
            isinstance(self.replication, tuple)
            and len(self.replication) == 2
            and self.replication[0] == "auto"
        ):
            return int(self.replication[1])
        return 0

    def describe(self) -> dict:
        """JSON-friendly view of the plan (serve.py stats reporting)."""
        ranges = self.ranges
        if isinstance(ranges, tuple):
            ranges = list(ranges)
        rep = self.replication
        if self.auto_replicas():
            rep = f"auto:{self.auto_replicas()}"
        elif rep is not None:
            rep = {str(s): r for s, r in rep}
        return {
            "shards": self.shards,
            "ranges": "equal" if ranges is None else ranges,
            "replication": rep,
            "policy": self.policy,
        }


def propose_starts(
    weights, num_shards: int, *, n: int | None = None
) -> np.ndarray:
    """Balanced shard-start boundaries from a per-vertex weight histogram.

    Cuts the cumulative weight curve at the ``i/num_shards`` quantiles —
    each shard's range carries as close to ``1/num_shards`` of the total
    weight as whole vertices allow — then clamps the cuts to strictly
    increasing boundaries so every shard keeps a non-empty range even when
    the histogram collapses onto a few vertices. A zero (or empty) histogram
    degenerates to the equal-width split.
    """
    w = np.asarray(weights, np.float64).reshape(-1)
    if n is None:
        n = len(w)
    elif len(w) != n:
        raise EngineConfigError(
            f"weight histogram has {len(w)} entries for n={n} vertices"
        )
    num_shards = int(num_shards)
    if not 1 <= num_shards <= max(n, 1):
        raise EngineConfigError(
            f"cannot split n={n} vertices into {num_shards} shards"
        )
    if w.size and (not np.all(np.isfinite(w)) or np.any(w < 0)):
        raise EngineConfigError("weights must be finite and non-negative")
    starts = np.zeros(num_shards, np.int64)
    if not w.size or float(w.sum()) <= 0.0:
        rows = -(-n // num_shards)  # ceil: the equal-width fallback
        return np.minimum(
            np.arange(num_shards, dtype=np.int64) * rows,
            np.arange(num_shards, dtype=np.int64) + n - num_shards,
        )
    c = np.cumsum(w)
    targets = c[-1] * np.arange(1, num_shards, dtype=np.float64) / num_shards
    cuts = np.searchsorted(c, targets, side="left") + 1
    for i, cut in enumerate(cuts, start=1):
        lo = int(starts[i - 1]) + 1           # strictly increasing
        hi = n - (num_shards - i)             # room for the shards after
        starts[i] = min(max(int(cut), lo), hi)
    return starts
