"""Device-resident batched kNN serving engine — the production query surface.

``KNNIndex`` (core/index.py) is the paper's host view: one numpy row scan per
query, one heap loop per update. That shape cannot serve heavy traffic — every
call pays Python dispatch, and nothing batches. ``QueryEngine`` keeps the
index as live device ``(n+1, k)`` id/dist tables (the construction sweeps'
layout, dummy row last) and exposes the paper's three operations in batched,
jitted form:

* ``query_batch(us, k)`` — one row gather + per-query k mask for a whole
  batch of queries (Theorem 4.3's O(k) scan, vectorized); and
  ``query_progressive_batch`` which yields the first-i prefix incrementally
  (Theorem 4.4) from a single gather.

* staged updates — ``stage_insert`` / ``stage_delete`` / ``stage_move``
  accumulate object updates in an arrival-order queue; ``flush_updates``
  coalesces the queue to its net object-set delta and applies it as ONE fused
  device batch against the tables.

  Coalescing semantics (per object, in queue order): an insert followed by a
  delete of the same object cancels to nothing; a delete followed by an
  insert of the same object is a no-op (the index is a pure function of the
  final object set — Theorems 6.2/6.4); move chains collapse to their
  endpoint (``a->b`` then ``b->c`` is ``a->c``; a chain returning to its
  origin cancels). The per-flush stats dict reports the pure insert/delete
  counts, the net move count, and ``coalesced`` — how many staged ops the
  folding eliminated.

  Application is a single fused pipeline, not a delete pass chased by an
  insert pass: one device scan finds every row naming a deleted object
  (``ops.rows_containing``); the checkIns frontier for ALL staged inserts
  runs as one jitted multi-source pruned-relaxation program on device
  (``ops.frontier_relax`` rounds with changed-frontier narrowing — see
  ``EngineCore._insert_frontier``; the host ``updates.insert_affected_set``
  heap search survives as the per-object oracle and as the ``frontier =
  "host"`` baseline pipeline) against the pre-update k-th distances —
  insert-first semantics, the same order the scalar ``move_object`` oracle
  uses; any insert-affected row the pruning misses lost an entry to the
  deletions and is repaired as part of the purge set (see
  ``flush_updates``); then one ``ops.rows_purge_merge`` over
  the union of the hit rows and the frontier drops the deleted entries,
  merges the insert candidates and recompacts every affected row in a single
  gather/merge/scatter. Jacobi rounds of the construction merge
  (``ops.sweep_merge`` over the purged rows' bridge neighborhoods) then
  repair the deletion holes to a fixpoint — Algorithm 5's processDel, run
  breadth-first on device — with the source- and destination-side work
  sharing one changed-row frontier and one repair pass per round. For a
  moving fleet (each object deleted here, re-inserted a street away) the
  destination entries are already in the tables when repair starts, so the
  holes close in about one round instead of pulling replacements from far
  away. The scalar ``core/updates.py`` path is kept as the reference oracle;
  the batched path is property-tested ``indices_equivalent`` against it.

  The repair rounds use the merge's XLA form (functional gather-then-scatter)
  rather than the in-place Pallas kernel: repaired rows read each other, so
  the level-schedule disjointness the fused kernel's aliasing relies on does
  not hold here.

* ``save`` / ``load`` — one ``.npz`` artifact (ids, dists, k, object set,
  format version + shard meta) shared by ``knn_build.py --out`` and the
  serving loop.

Queries always see the last *flushed* state: the staged queue is invisible
until ``flush_updates``, which is exactly the paper's batch-update-arrival
(BUA) serving model, and what lets a server interleave large query batches
with periodic update batches without locking.

Epochs and snapshot isolation: the tables are *epoch-versioned*. Every flush
builds epoch ``e+1`` functionally from epoch ``e`` — the pipeline is pure
device programs reassigning the working references, never overwriting the
published buffers — and then performs one atomic swap (``EpochStore.publish``)
that makes ``e+1`` current. ``query_batch`` resolves its table snapshot at
dispatch, so a query issued at ANY point during a flush reads a whole epoch —
``e`` before the swap, ``e+1`` after — never a partially-repaired mixture,
and a failed flush rolls the working references back to epoch ``e`` with the
staged queue intact (retryable; serving never stops). ``keep_epochs`` (the
retention E) bounds device memory at E table versions — ≤ E·(n+1)·k·8 bytes
— and lets callers pin an older epoch: ``query_batch(..., epoch=e)``.

Durability: ``attach_journal`` / ``load(..., journal=...)`` pair the engine
with a write-ahead ``repro.core.journal.UpdateJournal`` — staged ops are
fsync'd before acknowledgment, flush commits append an epoch marker, and
``load`` replays the journal through the staged path (flushing at each
commit marker, then rolling any uncommitted tail forward as one final
flush), so a killed process recovers to byte-identical tables. Artifacts
carry a content checksum + schema version; corruption raises a typed
``ArtifactError`` (see ``repro.core.errors``) instead of serving garbage.

Fault injection: ``EngineCore._checkpoint(phase)`` is the chaos seam — a
no-op unless ``engine.checkpoint_hook`` is set. It fires at
``"post-journal-append"`` (a staged op just hit disk), ``"mid-repair-round"``
(after each Jacobi repair round), ``"pre-swap"`` (epoch ``e+1`` built, not
yet published) and ``"post-swap"`` (published + journal-committed). The
``tests/chaos`` suite drives it to simulate kill-at-any-point and to assert
the snapshot-isolation contract above.

Host/device traffic per flush: the update script and affected-row indices go
up; a changed-row mask per frontier/repair round (which narrows the next
round's receiver set) and, once the frontier converges, the affected rows'
distance tiles come back. The (n,) k-th-distance column — the checkIns
pruning bound — never leaves the device: the frontier rounds read it
straight off the live distance table, so per-flush readback is proportional
to the affected set, not to n. Queries move only the query ids up and the
(B, k) result tiles back.

Everything above that is *layout-independent* — the staged queue and its
coalescing, query stat bookkeeping, the flush orchestration (delete scan ->
batched device checkIns frontier -> fused purge+merge -> breadth-first
repair with its changed-row frontier narrowing), persistence and the stats
surface (including the per-phase flush timings) — lives
in ``EngineCore``. ``QueryEngine`` supplies the single-device table layout
and device ops; ``repro.core.sharded.ShardedQueryEngine`` supplies the
vertex-sharded multi-device layout on top of the same core, which is what
keeps the two engines drop-in interchangeable (and exactly equivalent, see
tests/core/test_sharded.py).
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
import time
import zipfile
import zlib
from collections import OrderedDict
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bngraph import BNGraph
from repro.core.errors import (
    ArtifactError,
    EngineConfigError,
    EpochError,
    QueryError,
    StagedUpdateError,
)
from repro.core.journal import UpdateJournal
from repro.core.construct_jax import build_knn_tables_jax
from repro.core.index import PAD_ID, KNNIndex
from repro.core.updates import insert_affected_set
from repro.analysis import sanitize
from repro.kernels import ops

_FORMAT = "repro-knn-index"
# v2 added shard meta; v3 adds the content checksum. Load accepts v1/v2
# artifacts unchanged (no checksum to verify) and refuses versions > 3.
_FORMAT_VERSION = 3
_MAX_REPAIR_ROUNDS = 256


def _tables_checksum(ids: np.ndarray, dists: np.ndarray, objects: np.ndarray) -> int:
    """Content checksum over the logical artifact payload (order matters)."""
    crc = zlib.crc32(np.ascontiguousarray(ids).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(dists).tobytes(), crc)
    return zlib.crc32(np.ascontiguousarray(objects).tobytes(), crc)


class EpochStore:
    """Epoch number -> immutable table snapshot, with keep-last-E retention.

    The store is the engine's single source of "what do queries read": the
    newest published epoch is current, ``snapshot()`` resolves it at call
    time (dispatch-time snapshot = the snapshot-isolation contract), and
    ``snapshot(e)`` pins an older retained epoch. Retention is strict
    keep-last-E — publishing epoch ``e`` evicts everything below
    ``e - keep + 1`` — which is what bounds device memory at E table
    versions. Snapshots are tuples of immutable device arrays, so retaining
    one is a reference, not a copy.
    """

    def __init__(self, keep: int = 2):
        self._snaps: OrderedDict[int, tuple] = OrderedDict()
        self._keep = 0
        self.keep = keep

    @property
    def keep(self) -> int:
        return self._keep

    @keep.setter
    def keep(self, e: int) -> None:
        e = int(e)
        if e < 1:
            raise EpochError(f"keep_epochs must be >= 1, got {e}")
        self._keep = e
        self._evict()

    @property
    def current(self) -> int:
        return next(reversed(self._snaps)) if self._snaps else -1

    def epochs(self) -> list[int]:
        return list(self._snaps)

    def publish(self, epoch: int, snap: tuple) -> None:
        """Atomically make ``epoch`` current (one dict insert — a query
        that resolved its snapshot before this call keeps reading the old
        epoch's buffers, which stay alive via its reference)."""
        self._snaps[epoch] = snap
        self._evict()

    def _evict(self) -> None:
        while len(self._snaps) > self._keep:
            self._snaps.popitem(last=False)

    def snapshot(self, epoch: int | None = None) -> tuple:
        return self.resolve(epoch)[1]

    def resolve(self, epoch: int | None = None) -> tuple[int, tuple]:
        """Resolve ``epoch`` (None = current, at call time) to the concrete
        ``(epoch number, snapshot)`` pair — one atomic read, so a caller
        that needs both (e.g. replica routing keyed by epoch) can never see
        a number from one epoch and buffers from another."""
        if epoch is None:
            epoch = self.current
        else:
            epoch = int(epoch)
        if epoch not in self._snaps:
            raise EpochError(
                f"epoch {epoch} is not retained (have {self.epochs()}); "
                f"raise keep_epochs to pin more history"
            )
        return epoch, self._snaps[epoch]


def _pow2_pad(x: int, lo: int = 8) -> int:
    """Next power of two >= x (>= lo): bounds distinct jit signatures."""
    return max(lo, 1 << (max(1, x) - 1).bit_length())


class EngineCore:
    """Layout-independent serving core shared by the scalar and sharded engines.

    Subclasses own the table storage and implement the device hooks:

    * ``_gather_batch(us, ks, snap, epoch)`` — the batched row gather
      behind ``query_batch`` (full index-k width; the core applies stats
      and the per-query width slice). ``snap`` is the epoch snapshot
      resolved at dispatch and ``epoch`` its number — the gather must read
      the snapshot, never the working tables, so queries stay
      snapshot-isolated from an in-flight flush; the epoch number lets a
      subclass key per-epoch serving state (replica buffers) consistently.
    * ``_table_snapshot()`` — the current working tables as an immutable
      snapshot tuple (references; JAX arrays are immutable), published to
      the ``EpochStore`` at each flush commit.
    * ``_restore_tables(snap)`` — reset the working references to a
      snapshot (the failed-flush rollback path).
    * ``_scan_delete_rows(deletes)`` — global row ids naming any deleted
      object (the vectorized checkDel membership scan).
    * ``_purge_merge(rows, deletes, cand_ids, cand_d)`` — the fused
      purge + candidate merge over one (unpadded) global row batch.
    * ``_repair_part(part)`` — one Jacobi re-merge of ``part`` rows against
      their bridge neighborhoods; returns the per-row changed mask.
    * the frontier provider seam — ``_frontier_init(src)`` allocates the
      multi-source tentative-distance state for one staged insert batch,
      ``_frontier_part(state, part)`` runs one pruned-relaxation round over
      a receiver-row bucket (returning the new state + changed mask), and
      ``_frontier_extract(state, rows, src)`` reads back the affected mask
      and distances for the touched rows. The round loop, receiver-set
      expansion, bucketing and candidate compaction run here
      (``_insert_frontier``), so the scalar and sharded frontiers share one
      trajectory and cannot drift.
    * ``_table_kth()`` — the (n,) k-th-distance column (float64 host
      array). Only the ``frontier = "host"`` baseline pipeline reads it;
      the device frontier keeps the column on device end to end.
    * ``_host_tables()`` — the logical (n, k) id/dist tables for ``save``.
    * ``to_index()`` — readback into the host ``KNNIndex`` view.

    The flush pipeline, the frontier/repair rounds' narrowing and all
    validation/coalescing/stat bookkeeping run here, once, so a sharded
    engine cannot drift from the scalar one in anything but the device
    layout.
    """

    def __init__(self, k: int, objects, *, bn: BNGraph | None, use_pallas: bool):
        # subclasses set ``self.n`` (and their tables) before calling super()
        self.k = int(k)
        self.use_pallas = bool(use_pallas)
        self.bn = bn
        self.frontier = "device"  # validated setter, see the property below
        self.halo = "collective"  # validated setter, see the property below
        obj = {int(o) for o in np.asarray(objects).ravel()}
        self._objects = obj
        self._pending = set(obj)
        self._staged: list[tuple[str, int]] = []
        self._nbr_ids: np.ndarray | None = None
        self._nbr_w: np.ndarray | None = None
        self._nbr_deg: np.ndarray | None = None
        self._nbr_by_t: dict[int, tuple[jax.Array, jax.Array]] = {}
        self._stats = {
            "queries_served": 0,
            "query_batches": 0,
            "last_batch_size": 0,
            "flushes": 0,
            "flushes_failed": 0,
            "inserts_applied": 0,
            "deletes_applied": 0,
            "moves_applied": 0,
            "coalesced": 0,
            "rows_repaired": 0,
            "repair_rounds_last": 0,
            "frontier_rounds_last": 0,
            "t_frontier_s": 0.0,
            "t_purge_merge_s": 0.0,
            "t_repair_s": 0.0,
        }
        # epoch-versioned serving state: epoch 0 is the constructor tables;
        # every flush publishes the next epoch and queries resolve their
        # snapshot at dispatch (see the module docstring)
        self.checkpoint_hook = None  # chaos seam: fn(engine, phase) or None
        self._journal: UpdateJournal | None = None
        self._epochs = EpochStore(keep=2)
        self._epoch_stats: dict[int, dict] = {}
        self._publish_epoch(0)
        self._epoch_stats[0] = {"origin": "build"}

    @property
    def frontier(self) -> str:
        """Which checkIns pipeline ``flush_updates`` runs: ``"device"``
        (default) is the batched multi-source ``ops.frontier_relax`` rounds;
        ``"host"`` replays the per-object ``insert_affected_set`` heap
        search (kept as the measurable baseline — see benchmarks exp14 —
        and as the oracle's twin). A plain attribute rather than a
        constructor knob: flipping pipelines mid-life is safe (both produce
        identical tables); anything but the two known modes raises so a
        typo cannot silently select the wrong pipeline."""
        return self._frontier

    @frontier.setter
    def frontier(self, mode: str) -> None:
        if mode not in ("device", "host"):
            raise EngineConfigError(
                f"frontier must be 'device' or 'host', got {mode!r}"
            )
        self._frontier = mode

    @property
    def halo(self) -> str:
        """How cross-shard state moves during repair/frontier rounds:
        ``"collective"`` (default) exchanges neighbor rows and gated send
        rows as capacity-padded ``all_gather`` multicasts inside the
        shard_map programs, and runs the receiver-set expansion on device;
        ``"host"`` replays the routed-gather halo (host-mediated fetches,
        kept as the measurable baseline — see benchmarks exp18 — and as
        the collective path's bit-identity twin). Same seam pattern as
        ``frontier``: a plain attribute, safe to flip mid-life (both modes
        produce identical tables), unknown modes raise. The scalar engine
        and the 1-shard layout have no shard boundary to exchange across,
        so the setting is inert there."""
        return self._halo

    @halo.setter
    def halo(self, mode: str) -> None:
        if mode not in ("collective", "host"):
            raise EngineConfigError(
                f"halo must be 'collective' or 'host', got {mode!r}"
            )
        self._halo = mode

    # ------------------------------------------------------------------
    # epochs / durability / fault injection
    # ------------------------------------------------------------------

    @property
    def epoch(self) -> int:
        """The current serving epoch: 0 at construction, +1 per flush."""
        return self._epochs.current

    @property
    def keep_epochs(self) -> int:
        """Retention E: how many table epochs stay resident (>= 1). Device
        memory for tables is bounded by E·(n+1)·k·(id_bytes+dist_bytes);
        raising E lets callers pin older epochs via
        ``query_batch(..., epoch=e)``. Lowering it evicts immediately."""
        return self._epochs.keep

    @keep_epochs.setter
    def keep_epochs(self, e: int) -> None:
        self._epochs.keep = e
        self._trim_epoch_stats()

    def retained_epochs(self) -> list[int]:
        return self._epochs.epochs()

    def epoch_stats(self, epoch: int | None = None) -> dict:
        """Per-epoch provenance: how the retained epoch was produced
        (``origin`` build/flush/recovery plus the flush's stats dict and
        wall time). Raises ``EpochError`` for evicted/unknown epochs."""
        epoch = self._epochs.current if epoch is None else int(epoch)
        if epoch not in self._epoch_stats:
            raise EpochError(
                f"epoch {epoch} has no retained stats "
                f"(have {sorted(self._epoch_stats)})"
            )
        return dict(self._epoch_stats[epoch])

    def _trim_epoch_stats(self) -> None:
        kept = set(self._epochs.epochs())
        self._epoch_stats = {
            e: s for e, s in self._epoch_stats.items() if e in kept
        }

    def _publish_epoch(self, epoch: int) -> None:
        """Publish the working tables as ``epoch`` (the atomic swap).
        Subclasses that keep their own epoch-indexed structures (the
        sharded engine's routing table) extend this — it is the ONE place
        an epoch becomes visible."""
        self._epochs.publish(epoch, self._table_snapshot())

    def _prepare_publish(self) -> None:
        """Last hook inside the flush's fallible region, right before the
        pre-swap checkpoint. Subclasses that stage *layout* changes (the
        sharded engine's repartition-on-flush) re-lay the working tables
        here, so the subsequent ``_publish_epoch`` makes the new tables and
        the new layout visible in the same atomic step — and a failure
        anywhere in here still rolls back through ``_restore_tables``."""

    def _checkpoint(self, phase: str) -> None:
        """Fault-injection seam: no-op unless ``checkpoint_hook`` is set.

        The chaos tests install a hook that raises (simulated
        kill-at-this-point) or issues queries (snapshot-isolation probes).
        Phases fired: ``post-journal-append``, ``mid-repair-round``,
        ``pre-swap``, ``post-swap`` — plus ``pre-repartition`` /
        ``mid-repartition`` when the sharded engine has a staged
        repartition riding the flush.
        """
        hook = self.checkpoint_hook
        if hook is not None:
            hook(self, phase)

    def attach_journal(self, journal, *, replay: bool = True) -> UpdateJournal:
        """Pair the engine with a write-ahead update journal.

        ``journal`` is an ``UpdateJournal`` or a path (opened/created).
        With ``replay=True`` (default) any records already in the journal
        are first replayed through the staged path: flush at each commit
        marker — reproducing the original flush boundaries, so the tables
        land byte-identical to the uncrashed engine's — then any
        uncommitted tail is staged and rolled forward as one final flush
        (which appends its own commit marker, making recovery idempotent).
        From then on every ``stage_*`` call appends + fsyncs its record
        before acknowledging, every flush commits an epoch marker, and
        ``save`` truncates the journal once the artifact embodies it.
        """
        if self._journal is not None:
            raise ArtifactError("engine already has a journal attached")
        if self._staged:
            raise ArtifactError(
                "attach_journal before staging updates: the "
                f"{len(self._staged)} already-staged ops predate the journal "
                "and would not be durable"
            )
        if isinstance(journal, (str, os.PathLike)):
            journal = UpdateJournal(journal)
        if replay:
            self._replay_journal(journal)
        self._journal = journal
        return journal

    def _replay_journal(self, journal: UpdateJournal) -> None:
        """Roll the journal forward through the oracle-equivalent staged
        path (see ``attach_journal``). Journaling is suppressed while
        replaying committed segments — their records are already on disk —
        and re-enabled for the tail's roll-forward flush so its commit
        marker is appended."""
        records = journal.replay()
        tail = False
        for rec in records:
            if rec[0] == "commit":
                self.flush_updates()
                self._epoch_stats[self.epoch]["origin"] = "recovery"
                tail = False
            elif rec[0] == "ins":
                self.stage_insert(rec[1])
                tail = True
            elif rec[0] == "del":
                self.stage_delete(rec[1])
                tail = True
            else:  # ("mov", u, v)
                self.stage_move(rec[1], rec[2])
                tail = True
        if tail:
            self._journal = journal  # the tail flush commits its marker
            try:
                self.flush_updates()
                self._epoch_stats[self.epoch]["origin"] = "recovery"
            finally:
                self._journal = None

    def _journal_op(self, op: tuple) -> None:
        """WAL discipline: the record is on disk (fsync'd) before the
        stage call acknowledges. A kill right after this point is the
        ``post-journal-append`` chaos site — the op replays on reload even
        though the caller may never have seen the ack (fsync completed, so
        applying it is the correct recovery)."""
        if self._journal is not None:
            self._journal.append_op(op)
            self._checkpoint("post-journal-append")

    @staticmethod
    def normalize_tables(
        ids, dists, k: int, bn: BNGraph | None
    ) -> tuple[int, jax.Array, jax.Array]:
        """Validate and normalize constructor tables to the engine layout.

        Accepts host/device (n, k) tables or (n+1, k) tables straight from
        the construction sweeps (dummy gather row already last, only
        recognized when ``bn`` pins down n); returns ``(n, ids, dists)``
        with the dummy row (PAD_ID, +inf) guaranteed present. One shared
        normalizer so the scalar and sharded constructors cannot drift.
        """
        ids = jnp.asarray(ids, jnp.int32)
        dists = jnp.asarray(dists, jnp.float32)
        if ids.ndim != 2 or ids.shape != dists.shape or ids.shape[1] != k:
            raise ValueError(f"tables must be (n, k)={ids.shape} with k={k}")
        if bn is not None and ids.shape[0] not in (bn.n, bn.n + 1):
            raise ValueError(f"tables have {ids.shape[0]} rows but graph has n={bn.n}")
        if bn is not None and ids.shape[0] == bn.n + 1:
            return ids.shape[0] - 1, ids, dists
        n = int(ids.shape[0])
        ids = jnp.concatenate([ids, jnp.full((1, k), PAD_ID, jnp.int32)], axis=0)
        dists = jnp.concatenate(
            [dists, jnp.full((1, k), jnp.inf, jnp.float32)], axis=0
        )
        return n, ids, dists

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def _ks_array(self, b: int, k) -> tuple[jax.Array, int]:
        # uploads are explicit device_puts of host arrays: an eager jnp.full
        # materializes its Python fill value through an implicit transfer,
        # which the sanitizer leg's transfer guard (rightly) rejects
        if k is None:
            return jax.device_put(np.full((b,), self.k, np.int32)), self.k
        ks = np.asarray(k, dtype=np.int32)
        if ks.ndim == 0:
            if int(ks) > self.k:
                raise QueryError(f"query k={int(ks)} exceeds index k={self.k}")
            return jax.device_put(np.full((b,), int(ks), np.int32)), int(ks)
        if ks.shape != (b,):
            raise QueryError(f"per-query k must have shape ({b},), got {ks.shape}")
        if ks.size and int(ks.max()) > self.k:
            raise QueryError(f"per-query k max={int(ks.max())} exceeds index k={self.k}")
        return jax.device_put(ks), self.k

    def _gather_batch(self, us: np.ndarray, ks: jax.Array, snap: tuple, epoch: int):
        """Batched row gather at full index-k width against the ``snap``
        epoch snapshot (never the working tables — see the class doc);
        ``us`` is a host array so a sharded engine can route queries by
        owner before the device roundtrip. ``epoch`` is the resolved epoch
        number of ``snap`` (for subclasses with epoch-keyed serving state,
        e.g. replica buffers behind the routing table)."""
        raise NotImplementedError

    def query_batch(self, us, k=None, *, epoch=None) -> tuple[jax.Array, jax.Array]:
        """Batched kNN: (B,) vertices -> ((B, k') ids, (B, k') dists).

        ``k`` may be None (index k), a scalar, or a (B,) array for mixed-k
        traffic; columns past a query's k hold the pad sentinel (-1, +inf).
        Raises ``QueryError`` when any requested k exceeds the index's k.

        ``epoch`` pins the read to a retained older epoch (``EpochError``
        if evicted); by default the snapshot is resolved at dispatch — the
        current epoch at THIS moment — so a flush in progress can neither
        block the query nor leak it a partially-repaired table.
        """
        us = np.asarray(us, dtype=np.int32)
        if us.ndim != 1:
            raise QueryError(f"queries must be a 1-D vertex array, got {us.shape}")
        epoch_r, snap = self._epochs.resolve(epoch)
        with sanitize.guard("query"):
            ks, width = self._ks_array(us.shape[0], k)
            ids, d = self._gather_batch(us, ks, snap, epoch_r)
        self._stats["queries_served"] += int(us.shape[0])
        self._stats["query_batches"] += 1
        self._stats["last_batch_size"] = int(us.shape[0])
        if width < self.k:
            ids, d = ids[:, :width], d[:, :width]
        return ids, d

    def query_progressive_batch(
        self, us, k=None, *, epoch=None
    ) -> Iterator[tuple[jax.Array, jax.Array]]:
        """Progressive batched output: yields the first-i prefix for
        i = 1..k from ONE gather — O(i) work to surface i results per query
        (Theorem 4.4, batched)."""
        ids, d = self.query_batch(us, k, epoch=epoch)
        for i in range(1, ids.shape[1] + 1):
            yield ids[:, :i], d[:, :i]

    # ------------------------------------------------------------------
    # staged updates
    # ------------------------------------------------------------------

    def _check_vertex(self, u: int) -> int:
        u = int(u)
        if not 0 <= u < self.n:
            raise StagedUpdateError(f"vertex {u} out of range [0, {self.n})")
        if self.bn is None:
            raise RuntimeError(
                "updates need the BN-Graph; build the engine with bn= or load(..., bn=)"
            )
        return u

    def stage_insert(self, u: int) -> int:
        """Queue an object insertion; returns the staged-queue depth."""
        u = self._check_vertex(u)
        if u in self._pending:
            raise StagedUpdateError(f"object {u} already present (or staged for insert)")
        self._journal_op(("ins", u))
        self._pending.add(u)
        self._staged.append(("ins", u))
        return len(self._staged)

    def stage_delete(self, u: int) -> int:
        """Queue an object deletion; returns the staged-queue depth."""
        u = self._check_vertex(u)
        if u not in self._pending:
            raise StagedUpdateError(f"object {u} absent (or staged for delete)")
        self._journal_op(("del", u))
        self._pending.discard(u)
        self._staged.append(("del", u))
        return len(self._staged)

    def stage_move(self, u: int, v: int) -> int:
        """Queue an object movement u -> v; returns the staged-queue depth.

        The moving-objects primitive: the object at vertex u relocates to
        vertex v (same object, new position). At flush time move chains
        collapse to their endpoints and the source purge, destination
        checkIns frontier and repair rounds all run as one fused device
        batch — cheaper than staging the delete and the insert separately.
        """
        u = self._check_vertex(u)
        v = self._check_vertex(v)
        if u == v:
            raise StagedUpdateError(f"move source and destination are both {u}")
        if u not in self._pending:
            raise StagedUpdateError(f"object {u} absent (or staged for delete)")
        if v in self._pending:
            raise StagedUpdateError(f"object {v} already present (or staged for insert)")
        self._journal_op(("mov", u, v))
        self._pending.discard(u)
        self._pending.add(v)
        self._staged.append(("mov", u, v))
        return len(self._staged)

    @property
    def queue_depth(self) -> int:
        return len(self._staged)

    @property
    def objects(self) -> np.ndarray:
        """The flushed candidate-object set M (staged updates not included)."""
        return np.array(sorted(self._objects), dtype=np.int32)

    def _nbr_tables(self) -> None:
        """Bind the BN-Graph's combined BNS adjacency (``bns_packed``).

        Valid neighbors are compacted to the front of each row so that a row
        with degree d is fully described by the first d columns; frontier and
        repair rounds then run on the (n+1, t) column slice of the smallest
        pow4 bucket t >= the batch rows' max degree instead of the global
        tau', mirroring the construction sweeps' shape bucketing. The padded
        host tables are built once per BNGraph and shared across engines;
        the per-width device slices are cached per engine (``_nbr_slice``).
        """
        if self._nbr_ids is None:
            packed = self.bn.bns_packed()
            self._nbr_ids = packed.ids
            self._nbr_w = packed.w
            self._nbr_deg = packed.deg
            self._nbr_indptr = packed.indptr
            self._nbr_indices = packed.indices

    def _t_bucket(self, rows: np.ndarray) -> int:
        """Smallest pow4 width (>= 8) covering the rows' max BNS degree."""
        t_max = int(self._nbr_deg[rows].max())
        t = 8
        while t < t_max:
            t *= 4
        return min(t, self._nbr_ids.shape[1])

    def _nbr_slice(self, t: int) -> tuple[jax.Array, jax.Array]:
        """Device (n+1, t) adjacency slice for one width bucket, cached."""
        if t not in self._nbr_by_t:
            self._nbr_by_t[t] = (
                jax.device_put(self._nbr_ids[:, :t]),
                jax.device_put(self._nbr_w[:, :t]),
            )
        return self._nbr_by_t[t]

    def _pad_rows(self, rows: np.ndarray) -> jax.Array:
        """Pad a row batch to a pow2 length with the dummy row id n.

        lo=64 keeps the set of distinct jit row-count signatures small (64,
        128, 256, ...) so a long-running service stops compiling after the
        first few flushes; merging a few dozen dummy rows costs nothing.
        """
        out = np.full(_pow2_pad(len(rows), lo=64), self.n, np.int32)
        out[: len(rows)] = rows
        return jax.device_put(out)

    # hooks the flush pipeline drives -----------------------------------

    def _padded_deletes(self, deletes: list[int]) -> np.ndarray:
        """Deleted-object ids pow2-padded with the dummy id n (never an
        object id, so never a hit): bounds the distinct jit signatures
        across flush sizes."""
        if not deletes:
            return np.full(1, self.n, np.int32)
        padded = np.full(_pow2_pad(len(deletes)), self.n, np.int32)
        padded[: len(deletes)] = deletes
        return padded

    def _table_snapshot(self) -> tuple:
        raise NotImplementedError

    def _restore_tables(self, snap: tuple) -> None:
        raise NotImplementedError

    def _table_bytes(self) -> int:
        """Device bytes of ONE table epoch (int32 ids + float32 dists)."""
        return (self.n + 1) * self.k * 8

    def _scan_delete_rows(self, deletes: list[int]) -> np.ndarray:
        raise NotImplementedError

    def _table_kth(self) -> np.ndarray:
        raise NotImplementedError

    def _purge_merge(self, rows, deletes, cand_ids, cand_d) -> None:
        raise NotImplementedError

    def _repair_part(self, part: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _frontier_init(self, src: np.ndarray):
        raise NotImplementedError

    def _frontier_part(self, state, part: np.ndarray):
        raise NotImplementedError

    def _frontier_round(self, state, nbrs: np.ndarray):
        """One frontier round over receiver set ``nbrs``: bucket by BNS
        degree, run each part, resolve the changed masks once the whole
        round is queued. A mask may be a deferred readback (the sharded
        collective halo returns a thunk): resolving after the loop lets
        the later buckets' plan/upload work overlap the earlier buckets'
        device compute. The sharded engine overrides this wholesale on
        the collective path to fuse the round into one program."""
        pending = []
        for part in self._bucket_parts(nbrs):
            state, changed_mask = self._frontier_part(state, part)
            pending.append((part, changed_mask))
        changed_parts = [
            p[(m() if callable(m) else m)[: p.size]] for p, m in pending
        ]
        return state, changed_parts

    def _frontier_extract(self, state, rows: np.ndarray, src: np.ndarray):
        raise NotImplementedError

    def _bucket_parts(self, rows: np.ndarray):
        """Split a row batch by BNS-degree width bucket (8/32/128/tau').

        Shared by the repair and frontier rounds: each part runs against the
        (n+1, t) adjacency slice of its bucket so the per-round candidate
        work is sized to the batch, not to the global tau'. The split is a
        pure function of the row ids, so the scalar and sharded engines
        partition identically (their round trajectories must match).
        """
        deg = self._nbr_deg[rows]
        cap = self._nbr_ids.shape[1]
        prev = 0
        for t in [b for b in (8, 32, 128) if b < cap] + [cap]:
            part = rows[(deg > prev) & (deg <= t)]
            prev = t
            if part.size:
                yield part

    def _repair(self, rows: np.ndarray) -> int:
        """Jacobi repair rounds over the purged rows; returns the round count.

        Round 1 re-merges every purged row; later rounds only the frontier:
        a row can improve again only if a BNS neighbor's row changed last
        round (BN adjacency is symmetric, so BNS(changed) IS that set).
        The frontier collapses fast, so later rounds are tiny batches.
        Within a round, rows are split by BNS-degree width bucket so the
        candidate tensor is sized to the batch, not to the global tau'.
        Only the frontier's *vertex ids* survive a round boundary — the row
        data itself never leaves the owning table (or, sharded, the owning
        shard) between rounds.
        """
        self._nbr_tables()
        active = rows
        rounds = 0
        while active.size and rounds < _MAX_REPAIR_ROUNDS:
            changed_parts = []
            for part in self._bucket_parts(active):
                changed_mask = self._repair_part(part)
                changed_parts.append(part[changed_mask[: part.size]])
            rounds += 1
            self._checkpoint("mid-repair-round")
            changed_rows = (
                np.concatenate(changed_parts) if changed_parts else np.empty(0, np.int32)
            )
            if changed_rows.size == 0:
                break
            active = self._repair_receivers(changed_rows, rows)
        else:
            if active.size:
                raise RuntimeError(
                    f"delete repair did not reach a fixpoint in "
                    f"{_MAX_REPAIR_ROUNDS} rounds"
                )
        return rounds

    def _frontier_pad_src(self, src: np.ndarray) -> np.ndarray:
        """Pad the staged-insert sources to a pow2 column count (-1 pads).

        Bounds the distinct jit signatures across flush sizes, exactly like
        ``_pad_rows`` does for row batches; the Pallas relax kernel wants a
        lane-aligned column count, so that path pads to 128 columns.
        """
        b = _pow2_pad(len(src), lo=(128 if self.use_pallas else 8))
        out = np.full(b, -1, np.int32)
        out[: len(src)] = src
        return out

    def _insert_frontier(
        self, inserts: list[int]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Batched checkIns frontier on device: Algorithm 4 lines 1-8 for
        ALL staged inserts as one multi-source pruned-relaxation program.

        Round r relaxes the BNS edges of every vertex whose tentative
        distance changed in round r-1 (round 1: the sources themselves),
        pruned on device by the live k-th-distance column — the checkIns
        test ``d < kth[w]``. Only changed-row masks and, after convergence,
        the affected rows' distance tiles cross the host boundary; the
        (n,) kth column never does. Returns ``(rows, cand_ids, cand_d,
        rounds)``: the affected rows (sorted) with their per-row compacted
        (inserted object, exact distance) candidate lists — the same
        contract as the ``frontier = "host"`` pipeline, which it is
        property-tested exact-set-equal against (the pruned-relaxation
        fixpoint is schedule-independent, so the Dijkstra oracle and these
        Jacobi rounds land on identical sets and distances).
        """
        self._nbr_tables()
        src = np.asarray(inserts, np.int32)
        state = self._frontier_init(src)
        active = np.unique(src)
        touched = [active]
        rounds = 0
        while active.size and rounds < _MAX_REPAIR_ROUNDS:
            nbrs = self._expand_receivers(active)
            state, changed_parts = self._frontier_round(state, nbrs)
            rounds += 1
            active = (
                np.concatenate(changed_parts)
                if changed_parts
                else np.empty(0, np.int32)
            )
            if active.size:
                touched.append(active)
        if active.size:
            raise RuntimeError(
                f"checkIns frontier did not reach a fixpoint in "
                f"{_MAX_REPAIR_ROUNDS} rounds"
            )
        rows = np.unique(np.concatenate(touched)).astype(np.int32)
        aff, dvals = self._frontier_extract(state, rows, src)
        return (*self._compact_candidates(rows, aff, dvals, src), rounds)

    def _repair_receivers(
        self, changed: np.ndarray, rows: np.ndarray
    ) -> np.ndarray:
        """Next repair round's active set: the BNS neighborhoods of the
        rows that changed, narrowed to the purged batch. BN adjacency is
        symmetric, so BNS(changed) IS the set of rows that can improve.
        The sharded engine overrides this to expand the neighborhood on
        device when ``halo == "collective"`` — the set is identical (the
        packed BNS adjacency is exactly lo ∪ hi), only where the set
        algebra runs moves."""
        nbrs = np.unique(
            np.concatenate(
                [self.bn.lo_ids[changed].ravel(),
                 self.bn.hi_ids[changed].ravel()]
            )
        )
        return np.intersect1d(nbrs[nbrs >= 0], rows).astype(np.int32)

    def _expand_receivers(self, active: np.ndarray) -> np.ndarray:
        """Next round's receiver set: the union of BNS neighborhoods of the
        changed vertices, via the packed adjacency's CSR triple (touches
        exactly the live edges, no padded columns)."""
        starts = self._nbr_indptr[active]
        counts = self._nbr_indptr[active + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, np.int32)
        exc = np.concatenate([[0], np.cumsum(counts)[:-1]])
        idx = np.repeat(starts - exc, counts) + np.arange(total)
        return np.unique(self._nbr_indices[idx]).astype(np.int32)

    @staticmethod
    def _compact_candidates(
        rows: np.ndarray, aff: np.ndarray, dvals: np.ndarray, src: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(touched rows, (R, B) affected mask + distances) -> the flush's
        per-row candidate arrays: affected columns compacted to the front in
        source order, width pow2-padded — the exact layout the host frontier
        builds, so ``_purge_merge`` sees identical inputs either way."""
        keep = aff.any(axis=1)
        rows, aff, dvals = rows[keep], aff[keep], dvals[keep]
        if rows.size == 0:
            return rows, np.empty((0, 1), np.int32), np.empty((0, 1), np.float32)
        p = _pow2_pad(int(aff.sum(axis=1).max()), lo=4)
        if p > aff.shape[1]:
            pad = ((0, 0), (0, p - aff.shape[1]))
            aff = np.pad(aff, pad)
            dvals = np.pad(dvals, pad, constant_values=np.inf)
            src = np.pad(src, (0, p - len(src)), constant_values=-1)
        order = np.argsort(~aff, axis=1, kind="stable")[:, :p]
        taken = np.take_along_axis(aff, order, axis=1)
        cand_ids = np.where(taken, src[order], -1).astype(np.int32)
        cand_d = np.where(
            taken, np.take_along_axis(dvals, order, axis=1), np.inf
        ).astype(np.float32)
        return rows, cand_ids, cand_d

    def _insert_frontier_host(
        self, inserts: list[int]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """The pre-batching checkIns pipeline: one sequential host heap
        search per staged insert (``insert_affected_set``, shared with the
        scalar oracle) fed by a full (n,) k-th-distance readback. Kept as
        the ``frontier = "host"`` baseline the exp14 benchmark measures the
        device pipeline against, and as the property tests' twin."""
        kth = self._table_kth()
        per_row: dict[int, list[tuple[int, float]]] = {}
        for u in inserts:
            affected = insert_affected_set(self.bn, lambda v: float(kth[v]), u)
            for v, d in affected.items():
                per_row.setdefault(v, []).append((u, d))
        rows = np.fromiter(sorted(per_row), np.int32, len(per_row))
        if rows.size == 0:
            return rows, np.empty((0, 1), np.int32), np.empty((0, 1), np.float32), 0
        p = _pow2_pad(max(len(c) for c in per_row.values()), lo=4)
        cand_ids = np.full((len(rows), p), -1, np.int32)
        cand_d = np.full((len(rows), p), np.inf, np.float32)
        for i, v in enumerate(rows.tolist()):
            for j, (u, d) in enumerate(per_row[v]):
                cand_ids[i, j] = u
                cand_d[i, j] = d
        return rows, cand_ids, cand_d, 0

    def _coalesced_moves(self, deletes: set, inserts: set) -> list[tuple[int, int]]:
        """Fold the staged queue's move chains to (origin, endpoint) pairs.

        Only chains whose origin is a net delete AND whose endpoint is a net
        insert count as moves — everything else has already coalesced away in
        the object-set delta (a chain that returns home, a moved-then-deleted
        object, ...). Purely a classification for the stats dict: the applied
        work is always the net set delta.
        """
        chain: dict[int, int] = {}  # current endpoint -> chain origin
        for op in self._staged:
            if op[0] == "mov":
                _, u, v = op
                chain[v] = chain.pop(u, u)
            else:
                chain.pop(op[1], None)  # a delete at the endpoint kills the chain
        # Two chains can share an origin (move away, re-insert at the origin,
        # move away again), so pair each origin/endpoint at most once.
        avail_o, avail_c = set(deletes), set(inserts)
        moves = []
        for c, o in sorted(chain.items()):
            if o != c and o in avail_o and c in avail_c:
                moves.append((o, c))
                avail_o.discard(o)
                avail_c.discard(c)
        return moves

    def flush_updates(self) -> dict:
        """Apply the staged queue as one fused vectorized device batch.

        The queue is coalesced to its net object-set delta (the index is a
        pure function of the final object set — Theorems 6.2/6.4 make the
        sequential replay land on the same tables; see the module docstring
        for the per-object folding rules). Application: find the delete-hit
        rows, run the batched device checkIns frontier for ALL insertions at
        once against the pre-update k-th distances (insert-first semantics —
        see the inline comment; ``self.frontier = "host"`` selects the
        per-object baseline pipeline instead), purge + merge the union of
        both row sets in one ``rows_purge_merge`` pass, then repair the
        deletion holes with breadth-first Jacobi rounds that source- and
        destination-side work share. Returns the per-flush stats dict (net
        insert/delete/move counts plus ``coalesced``, the staged ops the
        folding eliminated, and the frontier/repair round counts); the
        cumulative per-phase wall times land in ``stats()`` as
        ``t_frontier_s`` / ``t_purge_merge_s`` / ``t_repair_s``.
        """
        t_wall0 = time.perf_counter()
        staged = len(self._staged)
        del_set = self._objects - self._pending
        ins_set = self._pending - self._objects
        deletes = sorted(del_set)
        inserts = sorted(ins_set)
        moves = self._coalesced_moves(del_set, ins_set)
        n_pure_ins = len(inserts) - len(moves)
        n_pure_del = len(deletes) - len(moves)

        # Epoch e+1 is built on the working references; the published epoch
        # e snapshot keeps its own references to the old buffers, so queries
        # dispatched anywhere in here still read a whole epoch. Any failure
        # (a device error, or a chaos hook's simulated kill) rolls the
        # working references back to epoch e with the staged queue intact —
        # the flush is retryable and serving never stops.
        base = self._epochs.snapshot()
        # Sanitizer rail: the device flush pipeline runs under the transfer
        # guard (all uploads must be explicit device_puts); the "host"
        # frontier is the measured host baseline, exempt by definition.
        flush_guard = (
            sanitize.guard("flush")
            if self._frontier == "device"
            else contextlib.nullcontext()
        )
        try:
            with flush_guard:
                # -- delete side: which rows name a deleted object (device scan) --
                purged_rows = np.empty(0, np.int32)
                if deletes:
                    purged_rows = self._scan_delete_rows(deletes)

                # -- insert side: batched checkIns frontier, insert-first semantics --
                # The frontier prunes against the CURRENT (pre-update) k-th bounds,
                # exactly Algorithm 4 run before Algorithm 5 (the same order the
                # scalar ``move_object`` oracle uses). A row the pruning misses that
                # still needs a new object in the *final* tables must have had its
                # k-th distance raised by the deletions — i.e. it lost an entry, so
                # it is in the purge set and the repair rounds rebuild it from its
                # bridge neighbors anyway. Keeping the pre-update bounds keeps the
                # frontier as tight as the oracle's, instead of the unpruned sweep a
                # post-purge (unbounded) k-th would trigger.
                t0 = time.perf_counter()
                f_rounds = 0
                frows = np.empty(0, np.int32)
                fc_ids = fc_d = None
                if inserts:
                    provider = (
                        self._insert_frontier_host
                        if self.frontier == "host"
                        else self._insert_frontier
                    )
                    frows, fc_ids, fc_d, f_rounds = provider(inserts)
                t_frontier = time.perf_counter() - t0

                # -- one fused purge + merge over the union of both row sets --
                rounds = 0
                t_purge = t_repair = 0.0
                if purged_rows.size or frows.size:
                    t0 = time.perf_counter()
                    rows = np.union1d(purged_rows, frows).astype(np.int32)
                    p = fc_ids.shape[1] if frows.size else 1
                    cand_ids = np.full((len(rows), p), -1, np.int32)
                    cand_d = np.full((len(rows), p), np.inf, np.float32)
                    if frows.size:
                        pos = np.searchsorted(rows, frows)
                        cand_ids[pos] = fc_ids
                        cand_d[pos] = fc_d
                    self._purge_merge(rows, deletes, cand_ids, cand_d)
                    t_purge = time.perf_counter() - t0
                    # -- breadth-first repair of the deletion holes (shared frontier) --
                    if purged_rows.size:
                        t0 = time.perf_counter()
                        rounds = self._repair(purged_rows)
                        t_repair = time.perf_counter() - t0

                # -- staged layout changes (repartition-on-flush) ride the
                # same epoch: the hook re-lays the working tables so the
                # publish below swaps tables AND layout atomically
                self._prepare_publish()
            self._checkpoint("pre-swap")
        except BaseException:
            self._restore_tables(base)
            self._stats["flushes_failed"] += 1
            raise

        # -- atomic swap: publish epoch e+1, commit the journal segment --
        self._objects = set(self._pending)
        self._staged.clear()
        new_epoch = self.epoch + 1
        self._publish_epoch(new_epoch)
        if self._journal is not None:
            self._journal.commit(new_epoch)
        self._stats["flushes"] += 1
        self._stats["inserts_applied"] += n_pure_ins
        self._stats["deletes_applied"] += n_pure_del
        self._stats["moves_applied"] += len(moves)
        self._stats["coalesced"] += staged - (n_pure_ins + n_pure_del + len(moves))
        self._stats["rows_repaired"] += int(purged_rows.size) + int(frows.size)
        self._stats["repair_rounds_last"] = rounds
        self._stats["frontier_rounds_last"] = f_rounds
        self._stats["t_frontier_s"] += t_frontier
        self._stats["t_purge_merge_s"] += t_purge
        self._stats["t_repair_s"] += t_repair
        result = {
            "staged": staged,
            "inserts": n_pure_ins,
            "deletes": n_pure_del,
            "moves": len(moves),
            "coalesced": staged - (n_pure_ins + n_pure_del + len(moves)),
            "rows_purged": int(purged_rows.size),
            "rows_merged": int(frows.size),
            "repair_rounds": rounds,
            "frontier_rounds": f_rounds,
        }
        self._epoch_stats[new_epoch] = {
            "origin": "flush",
            "flush": dict(result),
            "t_wall_s": time.perf_counter() - t_wall0,
        }
        self._trim_epoch_stats()
        self._checkpoint("post-swap")
        if sanitize.enabled():
            ids_h, d_h = self._host_tables()
            sanitize.scan_tables(
                ids_h, d_h, self.n, context=f"flush -> epoch {new_epoch}"
            )
        return result

    # ------------------------------------------------------------------
    # persistence / stats
    # ------------------------------------------------------------------

    def _host_tables(self) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def _save_meta(self) -> dict:
        return {"shards": 1}

    def save(self, path) -> None:
        """Write the index artifact: one npz shared by build and serving.

        Saving with a non-empty staged queue raises ``ArtifactError`` (rather
        than silently flushing): staged updates are invisible to queries, so
        an implicit flush would make the saved artifact disagree with what
        the engine was serving at save time. Call ``flush_updates()`` first;
        the tables are then exactly the flushed state and round-trip
        bit-identically through ``load``.

        The stored tables are always the *logical* (n, k) layout in vertex
        order — shard padding is stripped — so an artifact saved by a
        sharded engine at N shards loads into a scalar engine or a sharded
        engine at M shards (reshard-on-load); the writer's shard count is
        recorded in the meta as provenance. The meta also carries a content
        checksum over (ids, dists, objects) that ``load_artifact`` verifies,
        so a corrupted file raises instead of serving garbage tables.

        If a journal is attached it is truncated AFTER the artifact is
        written: the artifact now embodies every committed record (staged
        queue is empty here), so the journal restarts empty.
        """
        if self._staged:
            raise ArtifactError(
                "flush_updates() before save(): staged updates pending"
            )
        ids, dists = self._host_tables()
        objects = self.objects
        meta = {
            "format": _FORMAT,
            "version": _FORMAT_VERSION,
            "n": self.n,
            "k": self.k,
            "epoch": self.epoch,
            "checksum": _tables_checksum(ids, dists, objects),
            **self._save_meta(),
        }
        np.savez_compressed(
            path,
            ids=ids,
            dists=dists,
            k=np.int64(self.k),
            objects=objects,
            meta=np.bytes_(json.dumps(meta).encode()),
        )
        if self._journal is not None:
            self._journal.truncate()

    def _extra_stats(self) -> dict:
        return {}

    def stats(self) -> dict:
        """Serving counters (merged into benchmark/serve JSON output)."""
        retained = self.retained_epochs()
        return {
            "n": self.n,
            "k": self.k,
            "num_objects": len(self._objects),
            "staged_queue_depth": len(self._staged),
            "epoch": self.epoch,
            "epochs_retained": len(retained),
            "keep_epochs": self.keep_epochs,
            "epoch_table_bytes": len(retained) * self._table_bytes(),
            **self._extra_stats(),
            **self._stats,
        }


def load_artifact(path) -> tuple[np.ndarray, np.ndarray, int, np.ndarray, dict]:
    """Read a ``save``/``knn_build --out`` npz: (ids, dists, k, objects, meta).

    Accepts the pre-engine ``knn_build`` npz too (no object set stored):
    M is recovered as the distance-0 entries — every object is its own
    0-th nearest neighbor, so exactly the objects appear at distance 0.

    Robustness (all raise ``ArtifactError``): a truncated or otherwise
    unreadable npz; a schema version newer than this code (forward skew —
    refusing beats misreading fields that did not exist yet); a content
    checksum that no longer matches the stored tables (bit rot, torn
    write). v1/v2 artifacts carry no checksum and load unverified.
    """
    try:
        with np.load(path) as z:
            ids = z["ids"]
            dists = z["dists"]
            k = int(z["k"])
            if "objects" in z.files:
                objects = z["objects"]
            else:
                objects = np.unique(ids[dists == 0.0])
                objects = objects[objects >= 0]
            meta = json.loads(bytes(z["meta"])) if "meta" in z.files else {}
    except (
        OSError,
        ValueError,
        EOFError,
        KeyError,
        zlib.error,
        zipfile.BadZipFile,
    ) as e:
        raise ArtifactError(f"{path}: truncated or corrupt artifact ({e})") from e
    version = int(meta.get("version", 1))
    if version > _FORMAT_VERSION:
        raise ArtifactError(
            f"{path}: artifact schema version {version} is newer than this "
            f"code understands (max {_FORMAT_VERSION}); refusing to guess"
        )
    if "checksum" in meta:
        got = _tables_checksum(ids, dists, objects)
        if got != int(meta["checksum"]):
            raise ArtifactError(
                f"{path}: content checksum mismatch "
                f"(stored {meta['checksum']}, computed {got}) — the file is "
                f"corrupt; rebuild or restore from a good copy"
            )
    return ids, dists, k, objects, meta


class QueryEngine(EngineCore):
    """Batched kNN serving over device-resident index tables (see module doc)."""

    def __init__(
        self,
        ids: np.ndarray | jax.Array,
        dists: np.ndarray | jax.Array,
        k: int,
        objects,
        *,
        bn: BNGraph | None = None,
        use_pallas: bool = False,
    ):
        self.n, self._vk_ids, self._vk_d = self.normalize_tables(ids, dists, k, bn)
        super().__init__(k, objects, bn=bn, use_pallas=use_pallas)

    # ------------------------------------------------------------------
    # construction / conversion
    # ------------------------------------------------------------------

    @classmethod
    def build(
        cls,
        bn: BNGraph,
        objects: np.ndarray,
        k: int,
        *,
        use_pallas: bool = False,
    ) -> "QueryEngine":
        """Construct on device (Algorithm 3 fused sweeps) and serve in place:
        the sweep result tables become the engine's live tables, no readback."""
        vk_ids, vk_d = build_knn_tables_jax(bn, objects, k, use_pallas=use_pallas)
        return cls(vk_ids, vk_d, k, objects, bn=bn, use_pallas=use_pallas)

    @classmethod
    def from_index(
        cls,
        index: KNNIndex,
        objects,
        *,
        bn: BNGraph | None = None,
        use_pallas: bool = False,
    ) -> "QueryEngine":
        """Upload a host ``KNNIndex`` (e.g. an oracle-built one)."""
        dists = np.where(index.ids >= 0, index.dists, np.inf).astype(np.float32)
        return cls(index.ids, dists, index.k, objects, bn=bn, use_pallas=use_pallas)

    def to_index(self) -> KNNIndex:
        """Read the tables back into the host ``KNNIndex`` view (oracle dtype)."""
        ids = np.array(self._vk_ids[: self.n])
        dists = np.where(ids >= 0, np.asarray(self._vk_d[: self.n], np.float64), np.inf)
        return KNNIndex(ids=ids, dists=dists, k=self.k)

    @property
    def tables(self) -> tuple[jax.Array, jax.Array]:
        """The live device (n+1, k) id/dist tables (dummy row last)."""
        return self._vk_ids, self._vk_d

    # ------------------------------------------------------------------
    # device hooks (single-device layout)
    # ------------------------------------------------------------------

    def _table_snapshot(self) -> tuple[jax.Array, jax.Array]:
        # JAX arrays are immutable and the flush pipeline reassigns the
        # working refs rather than writing through them, so a snapshot is
        # just the pair of references — zero-copy epoch retention.
        return self._vk_ids, self._vk_d

    def _restore_tables(self, snap: tuple) -> None:
        self._vk_ids, self._vk_d = snap

    def _gather_batch(self, us: np.ndarray, ks: jax.Array, snap: tuple, epoch: int):
        return ops.serve_gather(snap[0], snap[1], jax.device_put(us), ks)

    def _scan_delete_rows(self, deletes: list[int]) -> np.ndarray:
        del_arr = jax.device_put(self._padded_deletes(deletes))
        hit = np.asarray(ops.rows_containing(self._vk_ids, del_arr))
        return np.flatnonzero(hit).astype(np.int32)

    def _table_kth(self) -> np.ndarray:
        return np.asarray(self._vk_d[: self.n, -1], np.float64)

    def _purge_merge(self, rows, deletes, cand_ids, cand_d) -> None:
        r_pad = _pow2_pad(len(rows), lo=64)  # must match _pad_rows
        pad = ((0, r_pad - len(rows)), (0, 0))
        cand_ids = np.pad(cand_ids, pad, constant_values=-1)
        cand_d = np.pad(cand_d, pad, constant_values=np.inf)
        self._vk_ids, self._vk_d = ops.rows_purge_merge(
            self._vk_ids, self._vk_d, self._pad_rows(rows),
            jax.device_put(self._padded_deletes(deletes)),
            jax.device_put(cand_ids), jax.device_put(cand_d), self.k,
            use_pallas=self.use_pallas,
        )

    def _repair_part(self, part: np.ndarray) -> np.ndarray:
        nbr_tab, w_tab = self._nbr_slice(self._t_bucket(part))
        self._vk_ids, self._vk_d, changed_mask = _repair_round(
            nbr_tab, w_tab, self._pad_rows(part), self._vk_ids, self._vk_d
        )
        return np.asarray(changed_mask)

    # frontier provider (single-device layout): the multi-source tentative
    # distance state is one (n+1, B) device matrix; the pruning column is
    # read straight off the live table inside the jitted round program, so
    # no kth values ever cross the host boundary.

    def _frontier_init(self, src: np.ndarray) -> jax.Array:
        self._fsrc = jax.device_put(self._frontier_pad_src(src))
        return _frontier_init_prog(self._fsrc, self._vk_ids.shape[0])

    def _frontier_part(self, state, part: np.ndarray):
        nbr_tab, w_tab = self._nbr_slice(self._t_bucket(part))
        state, changed = _frontier_round(
            nbr_tab, w_tab, self._pad_rows(part), state, self._vk_d,
            self._fsrc, self.use_pallas,
        )
        return state, np.asarray(changed)

    def _frontier_extract(self, state, rows: np.ndarray, src: np.ndarray):
        aff, d = _frontier_affected(self._pad_rows(rows), state, self._vk_d, self._fsrc)
        b = len(src)
        return np.asarray(aff)[: len(rows), :b], np.asarray(d)[: len(rows), :b]

    def _host_tables(self) -> tuple[np.ndarray, np.ndarray]:
        return np.asarray(self._vk_ids[: self.n]), np.asarray(self._vk_d[: self.n])

    @classmethod
    def load(
        cls,
        path,
        *,
        bn: BNGraph | None = None,
        use_pallas: bool = False,
        journal=None,
    ) -> "QueryEngine":
        """Load a ``save``/``knn_build --out`` artifact. ``bn`` enables updates.

        Accepts v1 artifacts and the pre-engine ``knn_build`` npz (see
        ``load_artifact``); shard meta from a sharded writer is ignored —
        the stored tables are always the logical vertex-order layout.

        ``journal`` (path or ``UpdateJournal``) attaches a write-ahead
        journal and REPLAYS it first: updates journaled after the artifact
        was saved — committed flushes and the uncommitted tail — are rolled
        forward through the staged path, recovering exactly the tables a
        killed process was serving (see ``attach_journal``). Requires
        ``bn`` when the journal is non-empty.
        """
        ids, dists, k, objects, _ = load_artifact(path)
        eng = cls(
            ids, dists.astype(np.float32), k, objects, bn=bn, use_pallas=use_pallas
        )
        if journal is not None:
            eng.attach_journal(journal)
        return eng


@functools.partial(jax.jit, static_argnames=("n1",))
def _frontier_init_prog(src, n1: int):
    """Allocate the (n+1, B) multi-source tentative-distance matrix: +inf
    everywhere except 0 at (src[i], i). Padded source columns (src = -1)
    park their zero on the dummy row, which is +inf by convention and never
    read unclamped, so they stay inert."""
    b = src.shape[0]
    dist = jnp.full((n1, b), jnp.inf, jnp.float32)
    rows = jnp.where(src >= 0, src, n1 - 1)
    vals = jnp.where(src >= 0, 0.0, jnp.inf).astype(jnp.float32)
    return dist.at[rows, jnp.arange(b)].set(vals)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _frontier_round(nbr_tab, w_tab, rows, dist, vk_d, src, use_pallas: bool):
    """One jitted frontier round: gather the receiver rows' BNS slices, run
    ``ops.frontier_relax`` against the live table's k-th column (device
    resident — sliced inside the program), and derive the changed mask that
    narrows the next round's receiver set. Distances only ever decrease, so
    ``new < old`` is exactly "changed"."""
    nbr = nbr_tab[rows]
    w = w_tab[rows]
    kth = vk_d[:, -1]
    new = ops.frontier_relax(nbr, rows, w, dist, kth, src, use_pallas=use_pallas)
    changed = jnp.any(new[rows] < dist[rows], axis=1)
    return new, changed


@jax.jit
def _frontier_affected(rows, dist, vk_d, src):
    """Affected test for the touched rows after convergence: checkIns
    against the k-th column, plus the source rows themselves (Algorithm 4
    admits the inserted object unconditionally). Returns the (R, B) mask
    and the distance tile — the only frontier data read back to host."""
    kth = vk_d[:, -1]
    d = dist[rows]
    aff = (d < kth[rows][:, None]) | (rows[:, None] == src[None, :])
    return aff, d


@jax.jit
def _repair_round(nbr_tab, w_tab, rows, vk_ids, vk_d):
    """One Jacobi repair round: every row in ``rows`` re-merges its own
    entries (extras tables = the live tables themselves) with its bridge
    neighbors' rows; returns the per-row changed mask the caller uses to
    narrow the next round's frontier. use_pallas=False in the merge is
    required, not a tuning choice — see the module docstring.
    """
    k = vk_ids.shape[1]
    nbr = nbr_tab[rows]
    w = w_tab[rows]
    new_ids, new_d = ops.sweep_merge(
        nbr, rows, w, vk_ids, vk_d, vk_ids, vk_d, k, use_pallas=False
    )
    changed = jnp.any(
        (new_ids[rows] != vk_ids[rows]) | (new_d[rows] != vk_d[rows]), axis=1
    )
    return new_ids, new_d, changed
