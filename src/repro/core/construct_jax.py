"""Level-synchronous TPU construction of the KNN-Index (Algorithm 3, batched).

The paper's bidirectional construction processes vertices one at a time in
rank order. The only true dependency is through BNS^< (bottom-up sweep) or
BNS^> (top-down sweep), so vertices sharing a DAG level are independent and
are processed as one fully-vectorised device step:

    gather neighbor rows -> shift by edge weight -> dedup top-k merge -> scatter

The merge is the `topk_merge` Pallas kernel (k rounds of VPU min-selection
over a VMEM candidate tile). Levels are padded to bucketed shapes (powers of
two) so the whole build compiles to a few dozen XLA programs regardless of n.

Value-equivalence with the sequential reference is exact (tested): a level
only ever reads rows written by strictly earlier levels — the same partial
order the paper's total rank refines.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bngraph import BNGraph
from repro.core.index import KNNIndex
from repro.kernels import ops

_INF = np.float32(np.inf)


def _next_pow2(x: int, lo: int = 8) -> int:
    return max(lo, 1 << (max(1, x) - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class LevelBatch:
    verts: np.ndarray    # (S,) int32, padded with n (dummy row id)
    nbr: np.ndarray      # (S, T) int32, padded with -1
    w: np.ndarray        # (S, T) float32, padded with +inf
    size: int            # true number of vertices in this level


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    n: int
    levels: list[LevelBatch]
    occupancy: float  # true cells / padded cells (padding-waste metric)


def prepare_sweep(bn: BNGraph, direction: str) -> SweepPlan:
    """Host-side schedule extraction: bucket-padded per-level batches."""
    if direction == "up":
        level_of, ids_tab, w_tab = bn.level_up, bn.lo_ids, bn.lo_w
    elif direction == "down":
        level_of, ids_tab, w_tab = bn.level_down, bn.hi_ids, bn.hi_w
    else:
        raise ValueError(direction)
    n = bn.n
    nlev = int(level_of.max()) + 1 if n else 0
    deg = (ids_tab >= 0).sum(axis=1)
    levels: list[LevelBatch] = []
    true_cells = 0
    pad_cells = 0
    order = np.argsort(level_of, kind="stable")
    bounds = np.searchsorted(level_of[order], np.arange(nlev + 1))
    for lv in range(nlev):
        vs = order[bounds[lv] : bounds[lv + 1]].astype(np.int32)
        if vs.size == 0:
            continue
        t_true = int(deg[vs].max()) if vs.size else 0
        s_pad = _next_pow2(len(vs))
        t_pad = _next_pow2(t_true, lo=1) if t_true else 1
        verts = np.full(s_pad, n, dtype=np.int32)
        verts[: len(vs)] = vs
        nbr = np.full((s_pad, t_pad), -1, dtype=np.int32)
        w = np.full((s_pad, t_pad), _INF, dtype=np.float32)
        nbr[: len(vs), :t_true] = ids_tab[vs][:, :t_true]
        w[: len(vs), :t_true] = w_tab[vs][:, :t_true].astype(np.float32)
        w[nbr < 0] = _INF
        levels.append(LevelBatch(verts=verts, nbr=nbr, w=w, size=len(vs)))
        true_cells += int(deg[vs].sum())
        pad_cells += s_pad * t_pad
    occ = true_cells / max(1, pad_cells)
    return SweepPlan(n=n, levels=levels, occupancy=occ)


def _sweep_step(verts, nbr, w, extra_ids, extra_d, vk_ids, vk_d, *, k: int, use_pallas: bool):
    """One level: gather -> shift -> dedup-top-k merge -> scatter."""
    s, t = nbr.shape
    valid = nbr >= 0
    nbr_c = jnp.where(valid, nbr, vk_ids.shape[0] - 1)  # dummy row
    g_ids = vk_ids[nbr_c]                       # (S, T, k)
    g_d = w[..., None] + vk_d[nbr_c]            # (S, T, k)
    g_ids = jnp.where(valid[..., None], g_ids, -1)
    cand_ids = jnp.concatenate([g_ids.reshape(s, t * k), extra_ids], axis=1)
    cand_d = jnp.concatenate([g_d.reshape(s, t * k), extra_d], axis=1)
    m_ids, m_d = ops.topk_merge(cand_ids, cand_d, k, use_pallas=use_pallas)
    vk_ids = vk_ids.at[verts].set(m_ids)
    vk_d = vk_d.at[verts].set(m_d)
    return vk_ids, vk_d


_sweep_step_jit = jax.jit(
    _sweep_step,
    static_argnames=("k", "use_pallas"),
    donate_argnums=(5, 6),
)


def run_sweep(
    plan: SweepPlan,
    extra_ids_full: np.ndarray,  # (n, E) per-vertex extra candidates
    extra_d_full: np.ndarray,    # (n, E)
    init_ids: np.ndarray | None,
    init_d: np.ndarray | None,
    k: int,
    *,
    use_pallas: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Run one direction of the construction. Returns (n, k) id/dist arrays.

    extra_*_full supply the non-neighbor candidate terms of Lemmas 5.12/5.21:
    bottom-up E=1 (the vertex itself when it is an object); top-down E=k (the
    vertex's own V_k^< row).
    """
    n = plan.n
    if init_ids is None:
        vk_ids = jnp.full((n + 1, k), -1, jnp.int32)
        vk_d = jnp.full((n + 1, k), jnp.inf, jnp.float32)
    else:
        vk_ids = jnp.concatenate([jnp.asarray(init_ids, jnp.int32), jnp.full((1, k), -1, jnp.int32)])
        vk_d = jnp.concatenate([jnp.asarray(init_d, jnp.float32), jnp.full((1, k), jnp.inf, jnp.float32)])
    e = extra_ids_full.shape[1]
    ex_ids_pad = np.concatenate([extra_ids_full, np.full((1, e), -1, np.int32)])
    ex_d_pad = np.concatenate([extra_d_full, np.full((1, e), _INF, np.float32)])
    for lb in plan.levels:
        extra_ids = jnp.asarray(ex_ids_pad[lb.verts])
        extra_d = jnp.asarray(ex_d_pad[lb.verts])
        vk_ids, vk_d = _sweep_step_jit(
            jnp.asarray(lb.verts),
            jnp.asarray(lb.nbr),
            jnp.asarray(lb.w),
            extra_ids,
            extra_d,
            vk_ids,
            vk_d,
            k=k,
            use_pallas=use_pallas,
        )
    return np.asarray(vk_ids[:n]), np.asarray(vk_d[:n])


def build_knn_index_jax(
    bn: BNGraph, objects: np.ndarray, k: int, *, use_pallas: bool = True
) -> KNNIndex:
    """Algorithm 3, level-batched on device: V_k^< sweep up, V_k sweep down."""
    n = bn.n
    is_obj = np.zeros(n, dtype=bool)
    is_obj[objects] = True

    # ---- bottom-up: V_k^< (Lemma 5.12) ----
    plan_up = prepare_sweep(bn, "up")
    own_ids = np.where(is_obj, np.arange(n, dtype=np.int32), -1)[:, None]
    own_d = np.where(is_obj, np.float32(0), _INF)[:, None].astype(np.float32)
    vkl_ids, vkl_d = run_sweep(plan_up, own_ids, own_d, None, None, k, use_pallas=use_pallas)

    # ---- top-down: V_k (Lemma 5.21) ----
    plan_down = prepare_sweep(bn, "down")
    vk_ids, vk_d = run_sweep(
        plan_down, vkl_ids, vkl_d, None, None, k, use_pallas=use_pallas
    )
    dists = np.where(vk_ids >= 0, vk_d.astype(np.float64), np.inf)
    return KNNIndex(ids=np.array(vk_ids), dists=np.array(dists), k=k)


def batched_query(vk_ids: jax.Array, vk_d: jax.Array, queries: jax.Array):
    """Device-side batched kNN query: pure row gather (Theorem 4.3, O(k))."""
    return vk_ids[queries], vk_d[queries]
