"""Device-resident level-synchronous construction of the KNN-Index (Alg. 3).

The paper's bidirectional construction processes vertices one at a time in
rank order. The only true dependency is through BNS^< (bottom-up sweep) or
BNS^> (top-down sweep), so vertices sharing a DAG level are independent and
are processed as one vectorised device step:

    gather neighbor rows -> shift by edge weight -> dedup top-k merge -> scatter

This module runs the whole sweep as a *fused, device-resident schedule*:

* ``prepare_sweep`` packs every level's ``verts``/``nbr``/``w`` into a small
  number of flat, contiguous device arrays — one set per (T, CHUNK) shape
  bucket — plus two tiny index arrays naming, for each fixed-size row chunk,
  which bucket it lives in and at which row offset. The entire schedule is
  uploaded **once** per sweep (explicit ``jax.device_put``); nothing else
  crosses the host/device boundary until the final result readback.
  Ragged-aware bucketing (power-of-4 neighbor widths, capped at the global
  max, two chunk tiers) caps padding waste; the plan reports ``occupancy``
  for the flat layout next to ``occupancy_levelwise`` for the seed's
  per-level power-of-two padding.

* ``run_sweep`` executes one direction as a **single jitted program**: a
  ``lax.fori_loop`` over chunks whose body ``lax.switch``es into one branch
  per shape bucket. Each branch dynamic-slices its chunk out of the flat
  schedule and applies ``ops.sweep_merge`` — on the Pallas path a single
  fused kernel per chunk that gathers neighbor k-lists straight out of the
  live HBM V_k tables into VMEM, shifts, merges (k rounds of dedup
  min-selection) and scatters the result rows, never materialising the
  (S, T*k + E) candidate tensor; on the XLA path the same math with an
  explicit candidate tensor. Distinct compilations per build are bounded by
  the number of shape-bucket signatures (one program per sweep), not by the
  number of levels.

* ``build_knn_index_jax`` chains the two sweeps entirely on device: the
  bottom-up result tables (V_k^<, including the dummy padding row) are handed
  to the top-down sweep as its per-vertex extra candidates (the paper's
  computation sharing, §5.3) with no host sync in between.

Value-equivalence with the sequential reference is exact (tested): a level
only ever reads rows written by strictly earlier levels — the same partial
order the paper's total rank refines.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bngraph import BNGraph
from repro.core.index import KNNIndex
from repro.kernels import ops

_INF = np.float32(np.inf)

# Row-chunk tiers: big levels stream in wide chunks, the long tail of tiny
# levels (often size 1) pads only to the sublane width.
CHUNK_SMALL = 8
CHUNK_LARGE = 64
_LARGE_LEVEL = 48  # levels at least this big use CHUNK_LARGE


def _t_bucket(t_true: int, cap: int) -> int:
    """Power-of-4 neighbor-width bucket (lo 4), capped at the global width."""
    p = 4
    while p < t_true:
        p *= 4
    return min(p, cap)


@dataclasses.dataclass(frozen=True)
class SweepBucket:
    """Flat device-resident schedule arrays for one (T, CHUNK) shape bucket."""

    t_pad: int
    chunk: int
    verts: jax.Array  # (R,) int32, padded rows hold n (the dummy row id)
    nbr: jax.Array    # (R, t_pad) int32, padded slots hold -1
    w: jax.Array      # (R, t_pad) float32, padded slots hold +inf


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """One direction of the construction, uploaded once and replayed on device."""

    n: int
    direction: str
    buckets: tuple[SweepBucket, ...]
    chunk_bucket: jax.Array   # (Nc,) int32: bucket index of each chunk
    chunk_off: jax.Array      # (Nc,) int32: first row of each chunk in its bucket
    num_chunks: int
    level_sizes: tuple[int, ...]
    occupancy: float            # true neighbor cells / flat padded cells
    occupancy_levelwise: float  # same metric under per-level pow2 padding (seed)

    @property
    def num_levels(self) -> int:
        return len(self.level_sizes)

    def bucket_signature(self) -> tuple[tuple[int, int], ...]:
        """The (T, CHUNK) shapes that bound distinct compilations."""
        return tuple((b.t_pad, b.chunk) for b in self.buckets)


def _next_pow2(x: int, lo: int = 8) -> int:
    return max(lo, 1 << (max(1, x) - 1).bit_length())


def prepare_sweep(bn: BNGraph, direction: str) -> SweepPlan:
    """Extract one direction's schedule and upload it to the device, once."""
    level_of, ids_tab, w_tab = bn.sweep_tables(direction)
    n = bn.n
    deg = (ids_tab >= 0).sum(axis=1)
    cap = _next_pow2(int(deg.max()), lo=4) if n else 4

    levels = bn.level_members(direction)
    acc: dict[tuple[int, int], dict] = {}
    chunk_bucket: list[int] = []
    chunk_off: list[int] = []
    key_index: dict[tuple[int, int], int] = {}
    true_cells = 0
    flat_cells = 0
    levelwise_cells = 0
    for vs in levels:
        t_true = int(deg[vs].max())
        t_pad = _t_bucket(t_true, cap)
        chunk = CHUNK_LARGE if len(vs) >= _LARGE_LEVEL else CHUNK_SMALL
        rows = -(-len(vs) // chunk) * chunk
        key = (t_pad, chunk)
        b = acc.setdefault(key, {"verts": [], "nbr": [], "w": [], "rows": 0})
        verts = np.full(rows, n, np.int32)
        verts[: len(vs)] = vs
        nbr = np.full((rows, t_pad), -1, np.int32)
        w = np.full((rows, t_pad), _INF, np.float32)
        t_copy = min(t_pad, ids_tab.shape[1])
        nbr[: len(vs), :t_copy] = ids_tab[vs][:, :t_copy]
        w[: len(vs), :t_copy] = w_tab[vs][:, :t_copy].astype(np.float32)
        w[nbr < 0] = _INF
        start = b["rows"]
        b["verts"].append(verts)
        b["nbr"].append(nbr)
        b["w"].append(w)
        b["rows"] += rows
        bid = key_index.setdefault(key, len(key_index))
        for c in range(rows // chunk):
            chunk_bucket.append(bid)
            chunk_off.append(start + c * chunk)
        true_cells += int(deg[vs].sum())
        flat_cells += rows * t_pad
        levelwise_cells += _next_pow2(len(vs)) * (_next_pow2(t_true, lo=1) if t_true else 1)

    buckets = []
    for key, _ in sorted(key_index.items(), key=lambda kv: kv[1]):
        b = acc[key]
        buckets.append(
            SweepBucket(
                t_pad=key[0],
                chunk=key[1],
                verts=jax.device_put(np.concatenate(b["verts"])),
                nbr=jax.device_put(np.concatenate(b["nbr"])),
                w=jax.device_put(np.concatenate(b["w"])),
            )
        )
    return SweepPlan(
        n=n,
        direction=direction,
        buckets=tuple(buckets),
        chunk_bucket=jax.device_put(np.asarray(chunk_bucket, np.int32)),
        chunk_off=jax.device_put(np.asarray(chunk_off, np.int32)),
        num_chunks=len(chunk_bucket),
        level_sizes=tuple(len(vs) for vs in levels),
        occupancy=true_cells / max(1, flat_cells),
        occupancy_levelwise=true_cells / max(1, levelwise_cells),
    )


def _sweep_program(
    bucket_data,   # tuple over buckets of (verts, nbr, w) device arrays
    chunk_bucket,
    chunk_off,
    ex_ids,
    ex_d,
    *,
    n: int,
    k: int,
    chunks: tuple[int, ...],   # static CHUNK per bucket (not derivable from shapes)
    use_pallas: bool,
    interpret: bool | None,
):
    """One full sweep as a single XLA program: fori_loop over chunks, switch
    over shape buckets. The V_k carry lives in HBM for the whole loop."""
    vk_ids = jnp.full((n + 1, k), -1, jnp.int32)
    vk_d = jnp.full((n + 1, k), jnp.inf, jnp.float32)

    def make_branch(bverts, bnbr, bw, chunk):
        def branch(off, vk_ids, vk_d):
            verts = jax.lax.dynamic_slice_in_dim(bverts, off, chunk)
            nbr = jax.lax.dynamic_slice_in_dim(bnbr, off, chunk)
            w = jax.lax.dynamic_slice_in_dim(bw, off, chunk)
            return ops.sweep_merge(
                nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d, k,
                use_pallas=use_pallas, interpret=interpret,
            )
        return branch

    branches = [
        make_branch(bv, bn_, bw, chunk)
        for (bv, bn_, bw), chunk in zip(bucket_data, chunks)
    ]

    def body(c, carry):
        vk_ids, vk_d = carry
        return jax.lax.switch(
            chunk_bucket[c], branches, chunk_off[c], vk_ids, vk_d
        )

    return jax.lax.fori_loop(0, chunk_bucket.shape[0], body, (vk_ids, vk_d))


_sweep_program_jit = jax.jit(
    _sweep_program,
    static_argnames=("n", "k", "chunks", "use_pallas", "interpret"),
)


def sweep_compile_count() -> int:
    """Distinct XLA programs compiled for sweeps so far in this process.

    Returns -1 when the jit cache introspection hook (a private JAX API) is
    unavailable, so callers can degrade to "unknown" instead of crashing.
    """
    cache_size = getattr(_sweep_program_jit, "_cache_size", None)
    return int(cache_size()) if cache_size is not None else -1


def run_sweep(
    plan: SweepPlan,
    extra_ids: jax.Array,  # (n+1, E) int32 per-vertex extra candidates, on device
    extra_d: jax.Array,    # (n+1, E) float32, on device
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Run one direction of the construction. Returns device (n+1, k) tables.

    extra_* supply the non-neighbor candidate terms of Lemmas 5.12/5.21:
    bottom-up, the vertex itself when it is an object; top-down, the vertex's
    own V_k^< row. Both are (n+1)-row device tables (dummy row last) so the
    sweep gathers them on device — zero host traffic inside the loop, which is
    why callers may wrap this in ``jax.transfer_guard("disallow")``.
    """
    if plan.num_chunks == 0:  # empty graph: nothing to sweep
        return (
            jnp.full((plan.n + 1, k), -1, jnp.int32),
            jnp.full((plan.n + 1, k), jnp.inf, jnp.float32),
        )
    bucket_data = tuple((b.verts, b.nbr, b.w) for b in plan.buckets)
    chunks = tuple(b.chunk for b in plan.buckets)
    return _sweep_program_jit(
        bucket_data,
        plan.chunk_bucket,
        plan.chunk_off,
        extra_ids,
        extra_d,
        n=plan.n,
        k=k,
        chunks=chunks,
        use_pallas=use_pallas,
        interpret=interpret,
    )


def object_extras(n: int, objects: np.ndarray, k: int) -> tuple[jax.Array, jax.Array]:
    """Bottom-up extras: each object is a distance-0 candidate for itself.

    Padded to E = k columns so both sweeps share extra shapes (and therefore
    compiled programs) wherever their bucket signatures coincide.
    """
    is_obj = np.zeros(n, dtype=bool)
    is_obj[objects] = True
    ex_ids = np.full((n + 1, k), -1, np.int32)
    ex_ids[:n, 0] = np.where(is_obj, np.arange(n, dtype=np.int32), -1)
    ex_d = np.full((n + 1, k), _INF, np.float32)
    ex_d[:n, 0] = np.where(is_obj, np.float32(0), _INF)
    return jax.device_put(ex_ids), jax.device_put(ex_d)


def build_knn_tables_jax(
    bn: BNGraph,
    objects: np.ndarray,
    k: int,
    *,
    use_pallas: bool = True,
    plans: tuple[SweepPlan, SweepPlan] | None = None,
    mesh=None,
    shard_starts=None,
) -> tuple[jax.Array, jax.Array]:
    """Algorithm 3, fused device sweeps: V_k^< up, then V_k down, no host sync.

    The bottom-up tables (dummy row included) feed the top-down sweep directly
    as its extra-candidate tables — the two sweeps share device buffers and
    nothing is read back. Returns the live device (n+1, k) int32/float32
    tables (dummy row last) — the layout ``QueryEngine`` serves from.
    ``plans`` lets a caller that already ran ``prepare_sweep`` (e.g. to report
    schedule stats) reuse the uploaded (up, down) schedules.

    With ``mesh`` (a 1-D ``jax.sharding.Mesh``), the result is re-laid into
    the vertex-sharded layout ``ShardedQueryEngine`` serves from — contiguous
    vertex ranges per device (equal-width, or the ``shard_starts`` boundary
    vector of an uneven ``PartitionPlan``), padded to the max range width,
    one dummy gather row per shard — still without reading the tables back
    to the host (see ``repro.core.sharded.shard_tables``).
    """
    ex_ids, ex_d = object_extras(bn.n, objects, k)
    plan_up, plan_down = plans or (prepare_sweep(bn, "up"), prepare_sweep(bn, "down"))

    # ---- bottom-up: V_k^< (Lemma 5.12) ----
    vkl_ids, vkl_d = run_sweep(plan_up, ex_ids, ex_d, k, use_pallas=use_pallas)
    # ---- top-down: V_k (Lemma 5.21), extras = own V_k^< rows, still on device ----
    vk_ids, vk_d = run_sweep(plan_down, vkl_ids, vkl_d, k, use_pallas=use_pallas)
    if mesh is None:
        return vk_ids, vk_d
    from repro.core.sharded import shard_tables

    return shard_tables(vk_ids, vk_d, bn.n, mesh, starts=shard_starts)


def build_knn_index_jax(
    bn: BNGraph, objects: np.ndarray, k: int, *, use_pallas: bool = True
) -> KNNIndex:
    """Device construction + readback into the host ``KNNIndex`` view."""
    vk_ids, vk_d = build_knn_tables_jax(bn, objects, k, use_pallas=use_pallas)
    # np.array (not asarray): the index must own writable host buffers, the
    # update algorithms (core/updates.py) patch rows in place.
    ids = np.array(vk_ids[: bn.n])
    dists = np.where(ids >= 0, np.asarray(vk_d[: bn.n], np.float64), np.inf)
    return KNNIndex(ids=ids, dists=dists, k=k)


def batched_query(vk_ids: jax.Array, vk_d: jax.Array, queries: jax.Array):
    """Device-side batched kNN query: pure row gather (Theorem 4.3, O(k))."""
    return vk_ids[queries], vk_d[queries]
