"""Device-side BN-Graph certificates (tropical-algebra checks).

Definition 5.3(2) says every G' edge weight equals the true shortest
distance. A cheap necessary-and-locally-sufficient certificate is
*relaxation stability*: the weighted adjacency A (with 0 diagonal, +inf
non-edges) must satisfy  min(A, A (min,+) A) == A on the edge support —
i.e. one tropical square cannot improve any edge. Algorithm 1's edge
deletion is exactly the per-vertex form of this relaxation, so the check is
the batched/TPU version of the paper's Step 2 invariant, evaluated with the
`minplus_matmul` Pallas kernel.

Used by tests and by launch/knn_build.py --verify for verification-scale
graphs (dense (n, n) tropical square; for production sizes the certificate
is run per level batch on the padded clique tiles instead).
"""
from __future__ import annotations

import numpy as np

from repro.core.bngraph import BNGraph
from repro.kernels import ops


def bngraph_dense_adjacency(bn: BNGraph) -> np.ndarray:
    a = np.full((bn.n, bn.n), np.inf, dtype=np.float32)
    np.fill_diagonal(a, 0.0)
    for v in range(bn.n):
        for u, w in bn.bns(v):
            a[v, u] = min(a[v, u], w)
    return a


def relaxation_stable(bn: BNGraph, *, use_pallas: bool = True, atol: float = 1e-5) -> bool:
    """True iff one (min,+) square cannot improve any existing G' edge."""
    import jax.numpy as jnp

    a = bngraph_dense_adjacency(bn)
    sq = np.asarray(ops.minplus_matmul(jnp.asarray(a), jnp.asarray(a), use_pallas=use_pallas))
    edges = np.isfinite(a) & ~np.eye(bn.n, dtype=bool)
    return bool(np.all(sq[edges] >= a[edges] - atol))


def certificate(bn: BNGraph, *, use_pallas: bool = True) -> dict:
    """Full certificate: relaxation stability + rank-direction consistency."""
    ok_relax = relaxation_stable(bn, use_pallas=use_pallas)
    ok_levels = True
    for v in range(bn.n):
        for u, _ in bn.bns_lower(v):
            ok_levels &= bn.rank[u] < bn.rank[v]
        for u, _ in bn.bns_higher(v):
            ok_levels &= bn.rank[u] > bn.rank[v]
    return {"relaxation_stable": ok_relax, "rank_consistent": bool(ok_levels),
            "ok": ok_relax and bool(ok_levels)}
