"""Host (numpy/python) reference implementations of the paper's algorithms.

These are the faithful, sequential forms:
  - dijkstra_knn / dijkstra_cons  : the paper's Dijkstra baselines
  - vk_less_sweep                 : lines 3-7 shared by Algorithms 2 and 3
  - knn_index_cons                : Algorithm 2  (bottom-up + per-vertex Dijkstra)
  - knn_index_cons_plus           : Algorithm 3  (bidirectional, no Dijkstra)

They serve as oracles for the TPU-side level-synchronous construction
(construct_jax.py) and as the paper-faithful baselines in benchmarks.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.bngraph import BNGraph
from repro.core.index import KNNIndex, index_from_lists
from repro.graph.csr import Graph


def _topk(cands: dict[int, float], k: int) -> list[tuple[int, float]]:
    """k smallest (dist, id) with distinct ids; deterministic tie-break by id."""
    return [(v, d) for d, v in heapq.nsmallest(k, ((d, v) for v, d in cands.items()))]


# ---------------------------------------------------------------------------
# Dijkstra oracle / baseline (Section 1 "straightforward approach")
# ---------------------------------------------------------------------------

def dijkstra_knn(g: Graph, is_object: np.ndarray, k: int, u: int) -> list[tuple[int, float]]:
    """Exact kNN by Dijkstra from u, early-terminated after k objects."""
    dist = np.full(g.n, np.inf)
    dist[u] = 0.0
    heap = [(0.0, u)]
    out: list[tuple[int, float]] = []
    while heap and len(out) < k:
        d, v = heapq.heappop(heap)
        if d > dist[v]:
            continue
        if is_object[v]:
            out.append((v, d))
        nbrs, ws = g.neighbors(v)
        for nb, w in zip(nbrs.tolist(), ws.tolist()):
            nd = d + w
            if nd < dist[nb]:
                dist[nb] = nd
                heapq.heappush(heap, (nd, nb))
    return out


def dijkstra_cons(g: Graph, objects: np.ndarray, k: int) -> KNNIndex:
    """Dijkstra-Cons baseline: n independent Dijkstra searches (Exp-4)."""
    is_object = np.zeros(g.n, dtype=bool)
    is_object[objects] = True
    rows = [dijkstra_knn(g, is_object, k, u) for u in range(g.n)]
    return index_from_lists(g.n, k, rows)


# ---------------------------------------------------------------------------
# Shared bottom-up sweep: decreasing-rank partial kNN  V_k^<  (Lemmas 5.11-5.14)
# ---------------------------------------------------------------------------

def vk_less_sweep(bn: BNGraph, objects: np.ndarray, k: int) -> list[list[tuple[int, float]]]:
    is_object = np.zeros(bn.n, dtype=bool)
    is_object[objects] = True
    vk_less: list[list[tuple[int, float]]] = [[] for _ in range(bn.n)]
    for r in range(bn.n):
        u = int(bn.order[r])
        cands: dict[int, float] = {u: 0.0} if is_object[u] else {}
        for w, phi in bn.bns_lower(u):
            for v, dwv in vk_less[w]:
                nd = phi + dwv
                old = cands.get(v)
                if old is None or nd < old:
                    cands[v] = nd
        vk_less[u] = _topk(cands, k)
    return vk_less


# ---------------------------------------------------------------------------
# Algorithm 2: bottom-up construction (BFS + Dijkstra over G'^>(u))
# ---------------------------------------------------------------------------

def knn_index_cons(bn: BNGraph, objects: np.ndarray, k: int) -> KNNIndex:
    vk_less = vk_less_sweep(bn, objects, k)
    adj = bn.adjacency()
    rank = bn.rank
    rows: list[list[tuple[int, float]]] = [[] for _ in range(bn.n)]
    for r in range(bn.n):
        u = int(bn.order[r])
        # line 9: construct G'^>(u) by BFS following increasing-rank edges.
        reach = {u}
        stack = [u]
        while stack:
            v = stack.pop()
            for nb in adj[v]:
                if rank[nb] > rank[v] and nb not in reach:
                    reach.add(nb)
                    stack.append(nb)
        # lines 10-11: Dijkstra from u inside G'^>(u). Edges of the subgraph:
        # (a,b) with a in reach and rank[b] > rank[a] (then b in reach too).
        dist_sub: dict[int, float] = {u: 0.0}
        heap = [(0.0, u)]
        while heap:
            d, v = heapq.heappop(heap)
            if d > dist_sub.get(v, np.inf):
                continue
            for nb, w in adj[v].items():
                if nb not in reach:
                    continue
                a, b = (v, nb) if rank[v] < rank[nb] else (nb, v)
                if a not in reach:
                    continue
                nd = d + w
                if nd < dist_sub.get(nb, np.inf):
                    dist_sub[nb] = nd
                    heapq.heappush(heap, (nd, nb))
        # lines 12-15: merge V_k^< of every w in G'^>(u) shifted by dist_sub.
        cands: dict[int, float] = {}
        for w in reach:
            dw = dist_sub.get(w, np.inf)
            for v, dv in vk_less[w]:
                nd = dw + dv
                old = cands.get(v)
                if old is None or nd < old:
                    cands[v] = nd
        rows[u] = _topk(cands, k)
    return index_from_lists(bn.n, k, rows)


# ---------------------------------------------------------------------------
# Algorithm 3: bidirectional construction (the paper's headline algorithm)
# ---------------------------------------------------------------------------

def knn_index_cons_plus(bn: BNGraph, objects: np.ndarray, k: int) -> KNNIndex:
    vk_less = vk_less_sweep(bn, objects, k)
    rows: list[list[tuple[int, float]]] = [[] for _ in range(bn.n)]
    for r in range(bn.n - 1, -1, -1):
        u = int(bn.order[r])
        cands: dict[int, float] = dict(vk_less[u])  # dist_<(u, .) term (Lemma 5.22)
        for w, phi in bn.bns_higher(u):
            for v, dwv in rows[w]:
                nd = phi + dwv
                old = cands.get(v)
                if old is None or nd < old:
                    cands[v] = nd
        rows[u] = _topk(cands, k)
    return index_from_lists(bn.n, k, rows)
