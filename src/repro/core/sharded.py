"""Vertex-sharded multi-device serving engine over row-partitioned tables.

KNN-Index's core asset is a flat, size-bounded (n+1, k) table — embarrassingly
partitionable by vertex, unlike the hierarchical indexes it replaces (PAPER.md
Section 4). ``ShardedQueryEngine`` exploits exactly that: the id/dist tables
are split row-wise across a 1-D ``jax.sharding.Mesh`` into contiguous vertex
ranges, padded to equal shard rows, and the full ``QueryEngine`` surface
(batched queries, progressive prefixes, staged updates with the fused
purge+merge flush and Jacobi repair, save/load) is served on the partitioned
layout. The shared serving core (``repro.core.engine.EngineCore``) supplies
the layout-independent logic, so the two engines cannot drift.

Layout
------
Shard ``s`` of ``S`` owns the contiguous vertex range
``[starts[s], starts[s+1])`` — a ``ShardLayout`` of arbitrary sorted start
boundaries, equal-width (``starts[s] = s * ceil(n/S)``) by default and
traffic-driven uneven under a ``PartitionPlan`` with explicit or ``auto``
ranges. Every shard's local block is padded to the same
``R = max range width`` rows plus one local dummy gather row — a local
``(R+1, k)`` block per device, stored as one global ``(S*(R+1), k)`` array
with ``NamedSharding(mesh, P("shard"))``. Vertex ``v`` lives at global padded
row ``owner(v) * (R+1) + (v - starts[owner(v)])``. Rows past a shard's range
width and the per-shard dummy rows hold the pad sentinel (-1, +inf); they
cost ``S*(R+1) - n`` wasted rows (reported as ``row_padding_overhead`` in
``stats()`` and the exp13 benchmark, so scaling numbers stay honest about the
memory cost — uneven ranges trade extra pad rows on the cold shards for a
smaller max per-shard query batch on the hot one, the exp17 win).

Repartition-on-flush: ``stage_repartition(starts)`` (or
``repartition(starts)``, which also flushes) records pending boundaries;
the next flush re-lays the working tables under them on device — inside the
flush's fallible region, so a crash rolls back to the old boundaries with
the staged queue intact — and the same atomic ``_publish_epoch`` step then
makes the new tables and the new layout visible together. The routing table
versions its layout per epoch, so pinned reads on old epochs keep routing
by the OLD boundaries (bit-identical time travel) while new queries route
by the new ``_starts``.

Execution model
---------------
* Queries: the host routes each query to its owner shard (one stable argsort
  per batch), pads the per-shard batches to a shared pow2 width, and a single
  ``shard_map``-ped gather serves all shards in one device roundtrip; the
  results are scattered back to the caller's batch order inside the same
  jitted program. Bit-identical to the scalar engine's ``query_batch``.
* Flush: the delete scan and the fused ``rows_purge_merge`` pass run
  per-shard via ``shard_map`` (``ops.shard_rows_*`` variants, which localize
  the global row ids against the shard's row offset on device); coalescing
  and the flush orchestration are the shared host logic.
* checkIns frontier: the staged inserts' multi-source tentative-distance
  matrix is row-sharded exactly like the tables, and each pruned-relaxation
  round runs shard-locally — the owner of a frontier vertex gates its
  distance row by its own k-th column (the checkIns test) before the row is
  exchanged, so the pruning bound never leaves its shard and only frontier
  *vertex ids + tentative distances* cross shard boundaries between rounds,
  through the same halo path the repair rounds use.
* Repair rounds: each round, the rows under repair re-merge against their
  bridge neighbors' rows. Neighbor rows may live on other shards, so each
  round first exchanges the (unique) neighbor rows — the boundary-vertex
  exchange of distributed moving-object kNN serving (arXiv 2512.23399) —
  then applies a per-shard merge.
* Halo modes: under ``halo = "collective"`` (the default) those cross-shard
  rows move as capacity-padded ``all_gather`` multicasts inside the
  shard_map programs and the receiver-set expansion runs on device as a
  psum'd presence mask, so per round only the integer index plans go up
  and one changed-row mask comes back; a plan that overflows
  ``halo_capacity`` falls back for that round. ``halo = "host"`` replays
  the routed-gather baseline (host-fetched unique rows, numpy set
  algebra) — kept as the exp18 measurable baseline and the collective
  path's bit-identity twin.

Epochs and routing
------------------
Ownership and epoch resolution go through ONE indirection, the
``ShardRoutingTable``: vertex -> owner shard (a searchsorted against the
stored shard-start boundaries — never inline ``v // R`` arithmetic at the
call sites) and epoch -> the sharded global buffers, with
``shard_buffers(epoch)`` resolving an individual shard to its device-local
buffer pair. ``flush_updates`` (the shared core) publishes each new epoch
through ``_publish_epoch``, which the sharded engine extends to swap the
routing table's epoch entry in the same atomic step — so a query dispatched
mid-flush routes to every shard's OLD buffers or every shard's NEW buffers,
never a mixture. The engine inherits the core's journal/WAL durability
unchanged (the journal records logical object updates, which are
layout-independent).

Replicated hot shards
---------------------
Skewed traffic (downtown absorbs most queries) makes one owner device the
ceiling no matter how many shards exist. ``set_replication({shard: R})``
expands the shard set into a *slot* set behind the same routing table:
slot ``j < S`` is shard ``j``'s primary, each extra replica appends one
slot on the next free device, and ``route(vs, policy=)`` spreads a hot
shard's queries across its slots (round-robin or least-outstanding).
Queries then run the SAME one-roundtrip shard_map gather on the wider
serving mesh; flushes keep writing only the primary layout, and each
``_publish_epoch`` ``jax.device_put``s the replicated shards' fresh local
blocks onto their replica devices in the same atomic swap — so every
replica serves exactly the primary's epoch snapshot (pinned reads stay
bit-identical mid-flush) and the seven-way oracle equality is untouched. A
replica fault degrades that batch to the primary-only path and counts a
``replica_errors`` stat instead of failing the query.

The engine is drop-in for ``QueryEngine``: same constructor shape, same
staged-update API, same artifact format. Artifacts always store the logical
(n, k) vertex-order tables, so an index saved at N shards loads at M shards
(or unsharded) — reshard-on-load.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import (
    Mesh,
    NamedSharding,
    PartitionSpec as P,
    SingleDeviceSharding,
)

from repro.core.bngraph import BNGraph
from repro.core.construct_jax import build_knn_tables_jax
from repro.core.engine import EngineCore, _pow2_pad, load_artifact
from repro.core.errors import EngineConfigError, EpochError, QueryError
from repro.core.index import KNNIndex
from repro.core.partition import PartitionPlan, propose_starts
from repro.kernels import ops


def make_mesh(shards: int | None = None) -> Mesh:
    """A 1-D device mesh over the first ``shards`` local devices.

    ``shards=None`` uses every visible device. On the CPU backend the device
    count is set at process start via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devs = jax.devices()
    if shards is None:
        shards = len(devs)
    if not 1 <= shards <= len(devs):
        raise ValueError(
            f"shards={shards} but only {len(devs)} devices are visible "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count)"
        )
    return Mesh(np.array(devs[:shards]), ("shard",))


def shard_tables(
    vk_ids: jax.Array, vk_d: jax.Array, n: int, mesh: Mesh, *, starts=None
) -> tuple[jax.Array, jax.Array]:
    """Re-lay single-device (n+1, k) tables into the sharded global layout.

    Stays on device: one gather through the padded-row -> source-row index
    map, then a resharding ``device_put`` — the construction sweeps' result
    feeds the sharded engine with no host readback. ``starts=None`` is the
    equal-width split; an explicit boundary vector lays the tables under
    uneven ranges (every shard still padded to the max range width).
    """
    shards = mesh.devices.size
    layout = (
        ShardLayout.equal(n, shards) if starts is None
        else ShardLayout.from_starts(n, starts)
    )
    src = np.full(shards * layout.block, n, np.int64)  # pads read the dummy row
    v = np.arange(n, dtype=np.int64)
    src[layout.padded_rows(v)] = v
    spec = NamedSharding(mesh, P("shard", None))
    src_dev = jnp.asarray(src)
    return (
        jax.device_put(vk_ids[src_dev], spec),
        jax.device_put(vk_d[src_dev], spec),
    )


class ShardLayout:
    """Immutable row layout of one epoch: boundaries + uniform block size.

    ``starts`` is the sorted shard-start vector (first entry 0); shard ``s``
    owns ``[starts[s], starts[s+1])`` and every shard's local block is
    padded to ``shard_rows = max range width`` rows plus one dummy gather
    row, so one ``(devices, block, k)`` shard_map program serves any
    boundary vector with the same max width. The routing table versions one
    ``ShardLayout`` per published epoch — pinned reads on old epochs keep
    resolving addresses under the boundaries they were published with.
    """

    __slots__ = ("n", "num_shards", "starts", "shard_rows")

    def __init__(self, n: int, starts: np.ndarray, shard_rows: int):
        self.n = int(n)
        self.starts = np.asarray(starts, np.int64)
        self.num_shards = len(self.starts)
        self.shard_rows = int(shard_rows)

    @classmethod
    def equal(cls, n: int, num_shards: int) -> "ShardLayout":
        """The default split: ``starts[s] = s * ceil(n/S)`` (trailing shards
        may be empty when S nearly divides n — seed-identical layout)."""
        rows = -(-int(n) // int(num_shards))  # ceil
        return cls(n, np.arange(num_shards, dtype=np.int64) * rows, rows)

    @classmethod
    def from_starts(cls, n: int, starts) -> "ShardLayout":
        """An explicit (possibly uneven) boundary vector, validated: first
        boundary 0, strictly increasing, every shard's range non-empty."""
        arr = np.asarray(starts, np.int64).reshape(-1)
        if not arr.size or arr[0] != 0:
            raise EngineConfigError(
                f"shard range boundaries must start at vertex 0, got "
                f"{arr.tolist()!r}"
            )
        if arr.size > 1 and not np.all(np.diff(arr) > 0):
            raise EngineConfigError(
                f"shard range boundaries must be strictly increasing, got "
                f"{arr.tolist()!r}"
            )
        if int(arr[-1]) > max(int(n) - 1, 0):
            raise EngineConfigError(
                f"shard range boundary {int(arr[-1])} leaves an empty range "
                f"(vertices end at {int(n) - 1})"
            )
        widths = np.diff(np.append(arr, int(n)))
        return cls(n, arr, int(widths.max()))

    @property
    def block(self) -> int:
        """Local rows per shard including the dummy gather row."""
        return self.shard_rows + 1

    @property
    def widths(self) -> np.ndarray:
        """Owned vertices per shard (0 for an empty trailing shard)."""
        return np.maximum(np.diff(np.append(self.starts, self.n)), 0)

    @property
    def is_equal(self) -> bool:
        rows = -(-self.n // self.num_shards)
        return self.shard_rows == rows and bool(
            np.array_equal(
                self.starts, np.arange(self.num_shards, dtype=np.int64) * rows
            )
        )

    def same_as(self, other: "ShardLayout") -> bool:
        return (
            self is other
            or (
                self.shard_rows == other.shard_rows
                and np.array_equal(self.starts, other.starts)
            )
        )

    def owner(self, vs: np.ndarray) -> np.ndarray:
        """Owner shard per vertex. ``vs`` must lie in [0, n] — n is the
        shared dummy/pad address; anything outside raises ``QueryError``
        instead of silently resolving (a negative id used to underflow
        ``searchsorted - 1`` into a plausible-but-wrong row of the LAST
        shard)."""
        vs = np.asarray(vs, np.int64)
        if vs.size and (int(vs.min()) < 0 or int(vs.max()) > self.n):
            bad = vs[(vs < 0) | (vs > self.n)]
            raise QueryError(
                f"vertex id {int(bad[0])} is outside [0, {self.n}] and "
                f"cannot be routed to a shard"
            )
        return np.minimum(
            np.searchsorted(self.starts, vs, side="right") - 1,
            self.num_shards - 1,
        )

    def padded_rows(
        self, vs: np.ndarray, own: np.ndarray | None = None
    ) -> np.ndarray:
        """Global padded-row address of each vertex: the owner's block base
        plus the vertex's offset from the owner's start boundary."""
        vs = np.asarray(vs, np.int64)
        if own is None:
            own = self.owner(vs)
        return own * self.block + (vs - self.starts[own])

    def serving_rows(
        self, vs: np.ndarray, own: np.ndarray, slots: np.ndarray
    ) -> np.ndarray:
        """Serving-layout padded-row address: the chosen slot's block base
        plus the vertex's offset from its *owner's* start boundary (every
        slot of a shard holds a copy of the same local block)."""
        return slots * self.block + (np.asarray(vs, np.int64) - self.starts[own])


class ShardRoutingTable:
    """The single shard indirection: vertex -> owner shard -> buffers per epoch.

    Two jobs, one table:

    * **Ownership.** ``owner(vs)`` is a ``searchsorted`` against the stored
      shard-start vertex boundaries — arbitrary sorted ``ShardLayout``
      boundaries, equal-width by default and traffic-driven uneven after a
      repartition — and ``padded_rows(vs)`` is the vertex's global
      padded-row address derived from the owner's stored start. Every
      routing decision in the engine reads THIS table instead of inlining
      ``v // R``. The layout is versioned per epoch: ``publish`` records
      the current ``ShardLayout`` alongside the buffers and
      ``layout(epoch)`` resolves it back, so a pinned read on an epoch
      published before a repartition still routes by the OLD boundaries.
    * **Epoch resolution.** ``publish(epoch, buffers)`` records the sharded
      global id/dist arrays serving an epoch, in the same atomic step the
      core's ``EpochStore`` swap runs; ``buffers(epoch)`` resolves a
      retained epoch back to them, and ``shard_buffers(epoch)`` resolves
      one step further — shard id -> (device, local ids buffer, local dists
      buffer) via the arrays' addressable shards. That is the "shard ->
      device buffers per epoch" map: per-shard epoch swap behind one
      indirection.
    * **Replication.** ``set_replication({shard: extras})`` expands the
      shard set into a *slot* set: slot ``j < S`` is shard ``j``'s primary
      and every extra replica appends one more slot (``slot_shard`` maps
      slot -> logical shard). ``owner()`` keeps answering with the logical
      shard; ``route(vs, policy=)`` resolves one step further to the slot
      each query should hit, under ``round_robin`` (a per-shard cursor) or
      ``least_outstanding`` (water-fill over ``outstanding`` + this batch).
      The replica *buffers* for an epoch ride the same ``publish`` call
      (``serving=``) so an epoch's primaries and replicas become visible in
      the same atomic step and pinned reads stay bit-identical on every
      slot.
    """

    def __init__(self, n: int, num_shards: int, starts=None):
        self.n = int(n)
        self.num_shards = int(num_shards)
        if starts is None:
            self._layout = ShardLayout.equal(self.n, self.num_shards)
        else:
            self._layout = ShardLayout.from_starts(self.n, starts)
            if self._layout.num_shards != self.num_shards:
                raise EngineConfigError(
                    f"boundary vector names {self._layout.num_shards} shards, "
                    f"table has {self.num_shards}"
                )
        self._layout_by_epoch: dict[int, ShardLayout] = {}
        self._by_epoch: OrderedDict[int, tuple] = OrderedDict()
        self._serving_by_epoch: dict[int, tuple | None] = {}
        self.replication: dict[int, int] = {}
        self.slot_shard = np.arange(self.num_shards, dtype=np.int64)
        self._slots_of: dict[int, np.ndarray] = {}
        self._rr: dict[int, int] = {}
        self.outstanding = np.zeros(self.num_shards, np.int64)

    # -- ownership (delegated to the CURRENT layout; per-epoch resolution
    # goes through ``layout(epoch)`` so pinned reads survive a repartition) -

    @property
    def current_layout(self) -> ShardLayout:
        return self._layout

    def set_layout(self, layout: ShardLayout) -> None:
        """Swap the CURRENT layout (repartition-on-flush applies the new
        boundaries here, in the same step it swaps the working tables);
        already-published epochs keep the layout they were published with."""
        if layout.n != self.n or layout.num_shards != self.num_shards:
            raise EngineConfigError(
                f"layout is for n={layout.n} x {layout.num_shards} shards, "
                f"table is n={self.n} x {self.num_shards}"
            )
        self._layout = layout

    @property
    def shard_rows(self) -> int:
        return self._layout.shard_rows

    @property
    def starts(self) -> np.ndarray:
        """The current layout's shard-start boundary vector (copy)."""
        return self._layout.starts.copy()

    @property
    def _starts(self) -> np.ndarray:
        # legacy spelling, kept because callers predate ShardLayout
        return self._layout.starts

    def owner(self, vs: np.ndarray) -> np.ndarray:
        """Owner shard per vertex under the CURRENT layout (see
        ``ShardLayout.owner`` for the [0, n] validation contract)."""
        return self._layout.owner(vs)

    def padded_rows(
        self, vs: np.ndarray, own: np.ndarray | None = None
    ) -> np.ndarray:
        """Global padded-row address per vertex under the CURRENT layout."""
        return self._layout.padded_rows(vs, own)

    def serving_rows(
        self, vs: np.ndarray, own: np.ndarray, slots: np.ndarray
    ) -> np.ndarray:
        """Serving-layout padded-row address under the CURRENT layout."""
        return self._layout.serving_rows(vs, own, slots)

    @property
    def num_slots(self) -> int:
        return len(self.slot_shard)

    def set_replication(self, plan: dict[int, int]) -> np.ndarray:
        """Install a shard -> extra-replica-count plan; returns the new
        slot -> logical-shard map. Slot ``j < num_shards`` stays shard
        ``j``'s primary; each extra replica appends one slot, grouped by
        shard in ascending shard order. Resets the routing cursors."""
        clean: dict[int, int] = {}
        for s, r in (plan or {}).items():
            s, r = int(s), int(r)
            if not 0 <= s < self.num_shards:
                raise EngineConfigError(
                    f"replication plan names shard {s}, have {self.num_shards}"
                )
            if r < 0:
                raise EngineConfigError(
                    f"replica count for shard {s} must be >= 0, got {r}"
                )
            if r:
                clean[s] = r
        self.replication = clean
        extras: list[int] = []
        self._slots_of = {}
        for s in sorted(clean):
            slots = [s]
            for _ in range(clean[s]):
                extras.append(s)
                slots.append(self.num_shards + len(extras) - 1)
            self._slots_of[s] = np.asarray(slots, np.int64)
        self.slot_shard = np.concatenate(
            [np.arange(self.num_shards, dtype=np.int64),
             np.asarray(extras, np.int64)]
        )
        self._rr = {}
        self.outstanding = np.zeros(self.num_slots, np.int64)
        return self.slot_shard

    def route(
        self, vs: np.ndarray, policy: str = "round_robin"
    ) -> tuple[np.ndarray, np.ndarray]:
        """Resolve vertices one step past ``owner``: (owner shard, serving
        slot) per vertex. Unreplicated shards route to their primary slot;
        a replicated shard's queries spread across its slot set under
        ``policy`` (every slot serves byte-identical buffers, so the choice
        affects load only, never results)."""
        own = self.owner(vs)
        return own, self.assign_slots(own, policy)

    def assign_slots(self, own: np.ndarray, policy: str = "round_robin") -> np.ndarray:
        if policy not in ("round_robin", "least_outstanding"):
            raise QueryError(
                f"unknown replica routing policy {policy!r} "
                f"(want 'round_robin' or 'least_outstanding')"
            )
        own = np.asarray(own, np.int64)
        slots = own.copy()  # primary slot id == shard id
        for s, sl in self._slots_of.items():
            m = np.flatnonzero(own == s)
            if not len(m):
                continue
            if policy == "round_robin":
                base = self._rr.get(s, 0)
                slots[m] = sl[(base + np.arange(len(m))) % len(sl)]
                self._rr[s] = (base + len(m)) % len(sl)
            else:
                slots[m] = np.repeat(sl, self._water_fill(sl, len(m)))
        return slots

    def _water_fill(self, sl: np.ndarray, count: int) -> np.ndarray:
        """Per-slot assignment counts that level ``outstanding`` + this
        batch across the shard's slots (the least-outstanding policy)."""
        load = self.outstanding[sl]
        lo, hi = int(load.min()), int(load.min()) + count
        while lo < hi:  # max level the batch can fill to
            mid = (lo + hi + 1) // 2
            if int(np.maximum(0, mid - load).sum()) <= count:
                lo = mid
            else:
                hi = mid - 1
        add = np.maximum(0, lo - load)
        rem = count - int(add.sum())
        if rem:
            add[np.argsort(load + add, kind="stable")[:rem]] += 1
        return add

    def record_dispatch(self, slots: np.ndarray) -> None:
        self.outstanding += np.bincount(slots, minlength=self.num_slots)

    def record_complete(self, slots: np.ndarray) -> None:
        self.outstanding -= np.bincount(slots, minlength=self.num_slots)

    # -- epoch -> buffers ----------------------------------------------

    def publish(self, epoch: int, buffers: tuple, keep=None, serving=None) -> None:
        """Swap in an epoch's buffers — and, when a replication plan is
        active, the matching replica (serving-layout) buffers — as one
        step, so a query can never resolve an epoch to another epoch's
        replicas. The CURRENT layout is recorded as the epoch's layout in
        the same step: after a repartition, pinned reads on older epochs
        keep resolving addresses under the boundaries they were published
        with."""
        epoch = int(epoch)
        self._by_epoch[epoch] = buffers
        self._serving_by_epoch[epoch] = serving
        self._layout_by_epoch.setdefault(epoch, self._layout)
        if keep is not None:
            self.trim(keep)

    def trim(self, keep) -> None:
        kept = set(keep)
        for e in [e for e in self._by_epoch if e not in kept]:
            del self._by_epoch[e]
        self._serving_by_epoch = {
            e: s for e, s in self._serving_by_epoch.items() if e in kept
        }
        self._layout_by_epoch = {
            e: lay for e, lay in self._layout_by_epoch.items() if e in kept
        }

    def epochs(self) -> list[int]:
        return list(self._by_epoch)

    def buffers(self, epoch: int) -> tuple:
        epoch = int(epoch)
        if epoch not in self._by_epoch:
            raise EpochError(
                f"epoch {epoch} is not in the routing table "
                f"(have {self.epochs()})"
            )
        return self._by_epoch[epoch]

    def layout(self, epoch: int) -> ShardLayout:
        """The ``ShardLayout`` a retained epoch was published under."""
        epoch = int(epoch)
        if epoch not in self._layout_by_epoch:
            raise EpochError(
                f"epoch {epoch} has no retained layout "
                f"(have {sorted(self._layout_by_epoch)})"
            )
        return self._layout_by_epoch[epoch]

    def shard_buffers(self, epoch: int) -> dict[int, tuple]:
        """shard id -> (device, local ids buffer, local dists buffer)."""
        ids_g, d_g = self.buffers(epoch)
        block = self.layout(epoch).block
        out: dict[int, tuple] = {}
        for si, sd in zip(ids_g.addressable_shards, d_g.addressable_shards):
            s = (si.index[0].start or 0) // block
            out[s] = (si.device, si.data, sd.data)
        return out

    def serving(self, epoch: int):
        """The epoch's replica (serving-layout) buffer pair, or None when
        it was published without an active replication plan."""
        return self._serving_by_epoch.get(int(epoch))

    def replica_buffers(self, epoch: int) -> dict[int, tuple]:
        """slot id -> (logical shard, device, local ids, local dists) for a
        retained epoch's serving layout — the replica-set analogue of
        ``shard_buffers`` (empty when the epoch has no replicas)."""
        serving = self.serving(epoch)
        if serving is None:
            return {}
        s_ids, s_d = serving
        block = self.layout(epoch).block
        out: dict[int, tuple] = {}
        for si, sd in zip(s_ids.addressable_shards, s_d.addressable_shards):
            slot = (si.index[0].start or 0) // block
            out[slot] = (int(self.slot_shard[slot]), si.device, si.data, sd.data)
        return out


_DEVICE_FN_CACHE: dict[tuple, dict] = {}


def _device_fns(mesh: Mesh, block: int, k: int) -> dict:  # replint: disable=REP003(jits are built once per devices/block/k key and memoized in _DEVICE_FN_CACHE)
    """The jitted shard_map programs for one (mesh, block-rows, k) layout.

    Cached at module level keyed by the device ids so every engine on the
    same layout shares one compile cache (the scalar engine gets this for
    free from its module-level jitted ops).
    """
    key = (tuple(d.id for d in mesh.devices.flat), block, k)
    if key in _DEVICE_FN_CACHE:
        return _DEVICE_FN_CACHE[key]

    spec2 = P("shard", None)

    def gather(ids_g, d_g, qglob, fidx, ks):
        def blk(ti, td, q):
            off = jax.lax.axis_index("shard") * block
            gi, gd = ops.shard_gather_rows(ti, td, q[0], off)
            return gi[None], gd[None]

        gi, gd = shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2),
            out_specs=(P("shard", None, None), P("shard", None, None)),
        )(ids_g, d_g, qglob)
        gi = gi.reshape(-1, k)[fidx]
        gd = gd.reshape(-1, k)[fidx]
        mask = jax.lax.broadcasted_iota(jnp.int32, gi.shape, 1) < ks[:, None]
        return jnp.where(mask, gi, -1), jnp.where(mask & (gi >= 0), gd, jnp.inf)

    def scan(ids_g, del_arr):
        def blk(ti, dl):
            return ops.shard_rows_containing(ti, dl)[None]

        return shard_map(
            blk, mesh=mesh, in_specs=(spec2, P(None)), out_specs=spec2
        )(ids_g, del_arr)

    def purge(ids_g, d_g, rglob, del_arr, ci, cd):
        def blk(ti, td, rq, dl, bci, bcd):
            off = jax.lax.axis_index("shard") * block
            ni, nd, ch = ops.shard_rows_purge_merge(
                ti, td, rq[0], off, dl, bci[0], bcd[0], k,
                use_pallas=False,  # XLA merge form inside shard_map, as in repair
            )
            return ni, nd, ch[None]

        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, P(None),
                      P("shard", None, None), P("shard", None, None)),
            out_specs=(spec2, spec2, spec2),
        )(ids_g, d_g, rglob, del_arr, ci, cd)

    # -- batched checkIns frontier (shard-local pruned relaxation) ---------
    # The multi-source tentative-distance matrix lives row-sharded exactly
    # like the tables: shard s owns the distance rows of its vertex range.
    # Each round the OWNER computes gated "send" rows (dist gated by its own
    # k-th column — the checkIns test), so the pruning bound never leaves
    # its shard; only frontier vertex ids and those tentative-distance rows
    # cross shard boundaries, through the same routed-gather halo path the
    # repair rounds use.

    def finit(src_grow):
        """(B,) global padded source rows (-1 pad) -> sharded dist matrix."""
        b = src_grow.shape[0]
        dist = jnp.full((mesh.devices.size * block, b), jnp.inf, jnp.float32)
        rows = jnp.where(src_grow >= 0, src_grow, block - 1)
        vals = jnp.where(src_grow >= 0, 0.0, jnp.inf).astype(jnp.float32)
        return dist.at[rows, jnp.arange(b)].set(vals)

    def fsend(d_g, dist_g, qglob, fidx, src_grow):
        """Routed gather of GATED distance rows: each owner applies the
        checkIns gate (dist < own kth, or the row is the column's source)
        before its rows leave the shard."""
        def blk(td, fd, q, sg):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, q[0], off)
            own = fd[loc]
            kth = td[loc][:, -1]
            gate = (own < kth[:, None]) | (q[0][:, None] == sg[None, :])
            return jnp.where(gate, own, jnp.inf)[None]

        out = shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, P(None)),
            out_specs=P("shard", None, None),
        )(d_g, dist_g, qglob, src_grow)
        return out.reshape(-1, dist_g.shape[1])[fidx]

    def fmin(dist_g, rglob, vals):
        """Shard-local min-update of the receiver rows + changed mask."""
        def blk(fd, rq, v):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, rq[0], off)
            own = fd[loc]
            new = jnp.minimum(own, v[0])
            ch = jnp.any(new < own, axis=1)
            return fd.at[loc].set(new), ch[None]

        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, P("shard", None, None)),
            out_specs=(spec2, spec2),
        )(dist_g, rglob, vals)

    def faff(d_g, dist_g, qglob, fidx, src_grow):
        """Post-convergence affected test, per owner shard: checkIns against
        the shard's k-th column plus the source rows themselves. Returns the
        (R, B) mask and distance tile in the caller's row order."""
        def blk(td, fd, q, sg):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, q[0], off)
            dd = fd[loc]
            kth = td[loc][:, -1]
            aff = (dd < kth[:, None]) | (q[0][:, None] == sg[None, :])
            return aff[None], dd[None]

        affs, ds = shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, P(None)),
            out_specs=(P("shard", None, None), P("shard", None, None)),
        )(d_g, dist_g, qglob, src_grow)
        b = dist_g.shape[1]
        return affs.reshape(-1, b)[fidx], ds.reshape(-1, b)[fidx]

    # -- collective halo (device-resident cross-shard rounds) -----------
    # The host-routed halo above round-trips every cross-shard row through
    # the host (_fetch_rows / _fetch_send + numpy set algebra). These
    # programs keep the whole round on device: the host only computes the
    # *index bookkeeping* (who serves which row — see _halo_plan) and the
    # rows themselves move shard-to-shard as one tiled all_gather per
    # round. serve is (S, Umax): serve[src] holds the global padded row
    # ids shard src must serve (-1 pads) — each unique neighbor of the
    # round's receivers exactly once, at its owner. After the tiled
    # all_gather every shard's (S*Umax, ...) receive buffer holds block
    # src = the rows shard src served, in serve[src] order — which is
    # exactly how _halo_plan numbers the slot matrix (slot S*Umax = miss).
    # A multicast layout, not a per-(src, dst)-pair all_to_all split: a
    # row needed by several receiver shards occupies ONE slot instead of
    # one per pair, which keeps the padded exchange near the halo's true
    # size (per-pair padding measured under 10% utilization on skewed
    # grid boundaries). Candidate construction (ops.halo_candidates /
    # halo_fold_min) and the local merge are the same trace-level math as
    # the routed path, so the tables stay bit-identical across halo modes.
    size = mesh.devices.size * block  # >= n: every vertex id fits

    def expand(nbr_g, aglob):
        """Device receiver-set expansion: each shard scatters the neighbor
        ids of its own routed active rows into a shared presence mask (the
        last slot absorbs -1 pads) and one psum unions the shards — O(E)
        scatter work instead of sorting an all_gather'd id tensor. The
        host's flatnonzero of the mask readback is the ascending unique
        set, exactly ``np.unique`` of the valid neighbor ids."""
        def blk(na, aq):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, aq[0], off)
            ids = na[loc].ravel()
            idx = jnp.where(ids < 0, size, ids)
            mask = jnp.zeros((size + 1,), jnp.int32).at[idx].set(1, mode="drop")
            return jax.lax.psum(mask, "shard")

        return shard_map(
            blk, mesh=mesh, in_specs=(spec2, spec2), out_specs=P(None),
        )(nbr_g, aglob)

    def rhalo(ids_g, d_g, serve, slot, wmat, rglob, del_arr):
        """One collective repair round: owners serve their slice of the
        round's unique neighbor rows, one tiled all_gather moves them,
        purge+merge at the receivers."""
        def blk(ti, td, sv, sl, wm, rg, dl):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, sv[0], off)  # (U,) to serve
            ri = jax.lax.all_gather(ti[loc], "shard", tiled=True)  # (S*U, k)
            rd = jax.lax.all_gather(td[loc], "shard", tiled=True)
            ci, cd = ops.halo_candidates(ri, rd, sl[0], wm[0], k)
            ni, nd, ch = ops.shard_rows_purge_merge(
                ti, td, rg[0], off, dl, ci, cd, k,
                use_pallas=False,  # XLA merge form inside shard_map
            )
            return ni, nd, ch[None]

        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, P("shard", None, None),
                      P("shard", None, None), spec2, P(None)),
            out_specs=(spec2, spec2, spec2),
        )(ids_g, d_g, serve, slot, wmat, rglob, del_arr)

    def fhalo(nbr_g, d_g, dist_g, serve, slot, wmat, rglob, src_grow):
        """One collective frontier round: owners gate their slice of the
        round's unique tentative-distance rows (the checkIns test — the
        k-th column never leaves its shard), one tiled all_gather moves
        the gated rows, and the receivers min-fold + min-update shard-
        locally. Also psums the NEXT round's receiver-set presence mask
        from the changed receivers' BNS rows, so the round-to-round
        expansion costs no extra program dispatch."""
        def blk(ng, td, fd, sv, sl, wm, rg, sg):
            off = jax.lax.axis_index("shard") * block
            loc = ops.shard_local_rows(block, sv[0], off)  # (U,) to serve
            own = fd[loc]                                  # (U, B)
            kth = td[:, -1][loc]                           # (U,)
            gate = (own < kth[:, None]) | (sv[0][:, None] == sg[None, :])
            recv = jax.lax.all_gather(                     # (S*U, B)
                jnp.where(gate, own, jnp.inf), "shard", tiled=True
            )
            cand = ops.halo_fold_min(recv, sl[0], wm[0])   # (R, B)
            lr = ops.shard_local_rows(block, rg[0], off)
            ownr = fd[lr]
            new = jnp.minimum(ownr, cand)
            ch = jnp.any(new < ownr, axis=1)
            # front-packed adjacency: a degree-t bucket's mask scatter
            # only needs the first t columns of the receivers' rows
            nb = jnp.where(ch[:, None], ng[lr][:, : sl.shape[-1]], -1)
            idx = jnp.where(nb < 0, size, nb).ravel()
            nmask = jnp.zeros((size + 1,), jnp.int32).at[idx].set(1, mode="drop")
            return fd.at[lr].set(new), ch[None], jax.lax.psum(nmask, "shard")

        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, spec2, P("shard", None, None),
                      P("shard", None, None), spec2, P(None)),
            out_specs=(spec2, spec2, P(None)),
        )(nbr_g, d_g, dist_g, serve, slot, wmat, rglob, src_grow)

    def fhalo_round(nbr_g, d_g, dist_g, src_grow, serves, slots, wmats, rglobs):
        """One fused collective frontier ROUND: every degree bucket's
        gate + all_gather + min-fold + min-update runs inside a single
        program, each bucket over its OWN serve slab (so the exchange
        volume equals the per-bucket fhalo calls it replaces). The
        tentative-distance state threads bucket-to-bucket — bucket b+1
        gates and gathers rows bucket b just improved — which is exactly
        the sequential per-part schedule the scalar and host-routed
        pipelines run, so not only the fixpoint but the whole ROUND
        TRAJECTORY matches them (test_sharded pins round counts
        engine-to-engine). Fusing the round into one dispatch (plus the
        psum'd next-round receiver mask) is what cuts the per-round
        overhead ~3x against per-bucket fhalo calls."""
        def blk(ng, td, fd, sg, svs, sls, wms, rgs):
            off = jax.lax.axis_index("shard") * block
            chs = []
            nmask = jnp.zeros((size + 1,), jnp.int32)
            for sv, sl, wm, rg in zip(svs, sls, wms, rgs):
                loc = ops.shard_local_rows(block, sv[0], off)
                own = fd[loc]                              # (U, B)
                kth = td[:, -1][loc]                       # (U,)
                gate = (own < kth[:, None]) | (sv[0][:, None] == sg[None, :])
                recv = jax.lax.all_gather(                 # (S*U, B)
                    jnp.where(gate, own, jnp.inf), "shard", tiled=True
                )
                cand = ops.halo_fold_min(recv, sl[0], wm[0])
                lr = ops.shard_local_rows(block, rg[0], off)
                ownr = fd[lr]
                new = jnp.minimum(ownr, cand)
                ch = jnp.any(new < ownr, axis=1)
                fd = fd.at[lr].set(new)
                chs.append(ch[None])
                # receivers in a degree-t bucket have <= t live neighbors
                # and the packed adjacency is front-packed, so the mask
                # scatter only needs the first t columns of their rows
                nb = jnp.where(ch[:, None], ng[lr][:, : sl.shape[-1]], -1)
                idx = jnp.where(nb < 0, size, nb).ravel()
                nmask = nmask.at[idx].set(1, mode="drop")
            return fd, tuple(chs), jax.lax.psum(nmask, "shard")

        nb_ = len(slots)
        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2, P(None), [spec2] * nb_,
                      [P("shard", None, None)] * nb_,
                      [P("shard", None, None)] * nb_, [spec2] * nb_),
            out_specs=(spec2, (spec2,) * nb_, P(None)),
        )(nbr_g, d_g, dist_g, src_grow, serves, slots, wmats, rglobs)

    # -- replica fan-out gather, two-phase ------------------------------
    # The serving mesh is wider than the shard mesh (primaries + replica
    # slots), so the one-jit gather's epilogue — reshape + [fidx] on a
    # replicated tile — would repeat its work per device. Instead the
    # shard_map tile stays sharded, one explicit d2d device_put
    # consolidates it, and a single-device jit restores the caller's batch
    # order: the epilogue is paid once, not once per slot. Replication
    # balances the per-slot batches, so the consolidated tile is small.

    def gather_tile(ids_g, d_g, qglob):
        def blk(ti, td, q):
            off = jax.lax.axis_index("shard") * block
            gi, gd = ops.shard_gather_rows(ti, td, q[0], off)
            return gi[None], gd[None]

        return shard_map(
            blk, mesh=mesh,
            in_specs=(spec2, spec2, spec2),
            out_specs=(P("shard", None, None), P("shard", None, None)),
        )(ids_g, d_g, qglob)

    def gather_epi(gi, gd, fidx, ks):
        gi = gi.reshape(-1, k)[fidx]
        gd = gd.reshape(-1, k)[fidx]
        mask = jax.lax.broadcasted_iota(jnp.int32, gi.shape, 1) < ks[:, None]
        return jnp.where(mask, gi, -1), jnp.where(mask & (gi >= 0), gd, jnp.inf)

    _DEVICE_FN_CACHE[key] = {
        "gather": jax.jit(gather),
        "gather_tile": jax.jit(gather_tile),
        "gather_epi": jax.jit(gather_epi),
        "scan": jax.jit(scan),
        "purge": jax.jit(purge),
        "kth": jax.jit(lambda d_g: d_g[:, -1]),
        "finit": jax.jit(finit, out_shardings=NamedSharding(mesh, P("shard", None))),
        "fsend": jax.jit(fsend),
        "fmin": jax.jit(fmin),
        "faff": jax.jit(faff),
        "expand": jax.jit(expand),
        "rhalo": jax.jit(rhalo),
        "fhalo": jax.jit(fhalo),
        "fhalo_round": jax.jit(fhalo_round, static_argnames=()),
    }
    return _DEVICE_FN_CACHE[key]


class ShardedQueryEngine(EngineCore):
    """Row-sharded multi-device drop-in for ``QueryEngine`` (see module doc)."""

    def __init__(
        self,
        ids,
        dists,
        k: int,
        objects,
        *,
        bn: BNGraph | None = None,
        shards: int | None = None,
        mesh: Mesh | None = None,
        use_pallas: bool = False,
        plan: PartitionPlan | None = None,
    ):
        plan = PartitionPlan.resolve(plan, shards=shards)
        self.mesh = mesh if mesh is not None else make_mesh(plan.shards)
        self.num_shards = int(self.mesh.devices.size)
        self.n, ids, dists = EngineCore.normalize_tables(ids, dists, k, bn)
        starts = self._plan_starts(plan, objects=objects)
        self._init_layout(int(k), starts=starts)
        self._ids_g, self._d_g = shard_tables(
            ids, dists, self.n, self.mesh, starts=starts
        )
        super().__init__(k, objects, bn=bn, use_pallas=use_pallas)
        self._apply_plan_replication(plan)

    def _plan_starts(self, plan: PartitionPlan, *, objects=None, saved=None):
        """Resolve a plan's ``ranges`` field to a boundary vector (or None
        for equal-width). Explicit ranges are used as given; ``auto`` asks
        the splitter for object-density-balanced boundaries (the build-time
        histogram; serve.py feeds the query histogram at runtime); None
        reuses a loader's ``saved`` boundaries when they still fit the
        shard count, else falls back to equal-width."""
        if isinstance(plan.ranges, tuple):
            starts = np.asarray(plan.ranges, np.int64)
            if len(starts) != self.num_shards:
                raise EngineConfigError(
                    f"plan names {len(starts)} range boundaries but the mesh "
                    f"has {self.num_shards} shards"
                )
            return starts
        if (
            saved is not None
            and len(saved) == self.num_shards
            and not ShardLayout.from_starts(self.n, saved).is_equal
        ):
            return np.asarray(saved, np.int64)
        if plan.ranges == "auto" and objects is not None and len(objects):
            if self.num_shards == 1:
                return None
            w = np.full(self.n, 1e-3)
            w[np.asarray(objects, np.int64)] += 1.0
            return propose_starts(w, self.num_shards)
        return None

    def _apply_plan_replication(self, plan: PartitionPlan) -> None:
        rep = plan.replication_dict()
        if rep:
            self.set_replication(rep, policy=plan.policy)
        elif plan.policy != self.replica_policy:
            self.replica_policy = plan.policy

    def _init_layout(self, k: int, starts=None) -> None:
        """Derive the host side of the partitioned layout (the routing
        table, shard_rows, the vertex -> global-padded-row map) and bind
        the shared device programs. Requires ``self.mesh``,
        ``self.num_shards`` and ``self.n`` to be set; the single source of
        the layout arithmetic for every constructor."""
        if self.num_shards > max(self.n, 1):
            raise EngineConfigError(
                f"cannot split n={self.n} rows into {self.num_shards} shards"
            )
        self.routing = ShardRoutingTable(self.n, self.num_shards, starts=starts)
        self.shard_rows = self.routing.shard_rows
        self._g_of_v = self.routing.padded_rows(np.arange(self.n, dtype=np.int64))
        self._make_device_fns(k)
        # repartition-on-flush state: boundaries staged for the next flush
        self._pending_layout: ShardLayout | None = None
        self._partition_stats = {"repartitions": 0}
        # collective halo state: the sharded BNS adjacency in the CURRENT
        # row layout (built lazily, dropped on every layout change so halo
        # row maps can never outlive their boundaries), plus the per-round
        # all_gather capacity cap — a round whose padded per-owner served-
        # row count exceeds it falls back to the routed host halo
        self._nbr_glob_g: jax.Array | None = None
        self.halo_capacity = 4096
        self._halo_stats = {
            "halo_rounds_collective": 0,
            "halo_fallbacks": 0,
        }
        # fused receiver-set expansion: collective frontier rounds psum
        # the next round's presence mask as a side output; None = not
        # armed (first round / host parts seen — expand runs standalone)
        self._fmask: list | None = None
        self._fmask_ok = True
        # replica serving state (inactive until set_replication installs a
        # plan): the serving mesh spans primaries + extra replica devices
        self.replica_policy = "round_robin"
        self.replica_fault_hook = None  # chaos seam: fn(engine) or None
        self._serving_mesh: Mesh | None = None
        self._serving_fns: dict | None = None
        self._cons_bufs: dict = {}  # pooled host staging buffers (see _consolidate)
        self._rstats = {
            "replica_queries": 0,
            "replica_batches": 0,
            "replica_errors": 0,
        }

    # ------------------------------------------------------------------
    # construction / conversion
    # ------------------------------------------------------------------

    @classmethod
    def build(
        cls,
        bn: BNGraph,
        objects: np.ndarray,
        k: int,
        *,
        shards: int | None = None,
        use_pallas: bool = False,
        plan: PartitionPlan | None = None,
    ) -> "ShardedQueryEngine":
        """Construct on device (Algorithm 3 fused sweeps) and serve sharded:
        the sweep result tables are re-laid into the partitioned layout with
        no host readback (``build_knn_tables_jax(..., mesh=)``). ``plan``
        is the unified ``PartitionPlan`` surface (``shards=`` is the legacy
        shim); ``ranges="auto"`` splits by object density at build time."""
        plan = PartitionPlan.resolve(plan, shards=shards)
        eng = cls.__new__(cls)  # skip __init__: the tables are born sharded
        eng.mesh = make_mesh(plan.shards)
        eng.num_shards = int(eng.mesh.devices.size)
        eng.n = bn.n
        starts = eng._plan_starts(plan, objects=objects)
        eng._init_layout(int(k), starts=starts)
        eng._ids_g, eng._d_g = build_knn_tables_jax(
            bn, objects, k, use_pallas=use_pallas, mesh=eng.mesh,
            shard_starts=starts,
        )
        EngineCore.__init__(eng, k, objects, bn=bn, use_pallas=use_pallas)
        eng._apply_plan_replication(plan)
        return eng

    @classmethod
    def from_index(
        cls,
        index: KNNIndex,
        objects,
        *,
        bn: BNGraph | None = None,
        shards: int | None = None,
        use_pallas: bool = False,
        plan: PartitionPlan | None = None,
    ) -> "ShardedQueryEngine":
        """Upload a host ``KNNIndex`` (e.g. an oracle-built one), sharded."""
        dists = np.where(index.ids >= 0, index.dists, np.inf).astype(np.float32)
        return cls(
            index.ids, dists, index.k, objects,
            bn=bn, shards=shards, use_pallas=use_pallas, plan=plan,
        )

    @classmethod
    def load(
        cls,
        path,
        *,
        bn: BNGraph | None = None,
        shards: int | None = None,
        use_pallas: bool = False,
        journal=None,
        replication: dict[int, int] | None = None,
        plan: PartitionPlan | None = None,
    ) -> "ShardedQueryEngine":
        """Load a ``save`` artifact into a sharded engine — reshard-on-load.

        The artifact stores the logical vertex-order tables, so the writer's
        shard count does not constrain the reader: ``shards=None`` re-shards
        across the saved count capped at the visible device count (an
        artifact saved at 8 shards still loads on a 2-device host), and an
        explicit ``shards=M`` overrides it entirely.

        A saved replication plan (shard -> extra replicas) is re-applied
        when it still describes this engine — same shard count as the
        writer and enough free devices to seat every replica — and dropped
        otherwise (the plan is keyed by shard id, so a reshard invalidates
        it; replicas are a serving concern, not an artifact one). Pass
        ``replication={...}`` to install a different plan, or ``{}`` to
        force-drop the saved one.

        ``journal`` attaches + replays a write-ahead journal exactly as in
        ``QueryEngine.load`` — the journal records logical object updates,
        so a journal written by a scalar (or differently-sharded) engine
        replays here and recovers the same logical tables.

        Saved uneven range boundaries (``meta["starts"]``) are re-applied
        when the reader keeps the writer's shard count and the plan does
        not name explicit ranges; a reshard drops them (boundaries are
        keyed by shard count, and the loaded tables re-lay either way).
        """
        plan = PartitionPlan.resolve(plan, shards=shards, replication=replication)
        ids, dists, k, objects, meta = load_artifact(path)
        shards = plan.shards
        if shards is None:
            shards = min(int(meta.get("shards", 1)), len(jax.devices()))
        ranges = plan.ranges
        if not isinstance(ranges, tuple):
            saved_starts = meta.get("starts")
            if saved_starts is not None and len(saved_starts) == shards:
                ranges = tuple(int(s) for s in saved_starts)
        eng = cls(
            ids, dists.astype(np.float32), k, objects,
            bn=bn, use_pallas=use_pallas,
            plan=dataclasses.replace(
                plan, shards=shards, ranges=ranges, replication=None
            ),
        )
        rep = plan.replication_dict()
        if rep is None and not plan.auto_replicas():
            saved = {
                int(s): int(r)
                for s, r in (meta.get("replication") or {}).items()
            }
            extras = sum(saved.values())
            if (
                saved
                and shards == int(meta.get("shards", 1))
                and shards + extras <= len(jax.devices())
            ):
                rep = saved
        if rep:
            eng.set_replication(rep, policy=plan.policy)
        if journal is not None:
            eng.attach_journal(journal)
        return eng

    def to_index(self) -> KNNIndex:
        """Read the sharded tables back into the host ``KNNIndex`` view."""
        ids = np.asarray(self._ids_g)[self._g_of_v]
        d = np.asarray(self._d_g)[self._g_of_v]
        dists = np.where(ids >= 0, d.astype(np.float64), np.inf)
        return KNNIndex(ids=ids, dists=dists, k=self.k)

    @property
    def tables(self) -> tuple[jax.Array, jax.Array]:
        """The live sharded (S*(R+1), k) global id/dist tables."""
        return self._ids_g, self._d_g

    # ------------------------------------------------------------------
    # epoch hooks (per-shard swap behind the routing table)
    # ------------------------------------------------------------------

    def _table_snapshot(self) -> tuple[jax.Array, jax.Array]:
        # sharded global arrays are immutable too (the flush reassigns the
        # working refs), so a snapshot is the pair of references — each one
        # pinning its per-device buffers for the epoch's lifetime
        return self._ids_g, self._d_g

    def _restore_tables(self, snap: tuple) -> None:
        self._ids_g, self._d_g = snap
        # a failed flush may have died mid-repartition, AFTER the working
        # layout swapped: re-sync to the published epoch's layout (the
        # current epoch is untouched by a failed flush). The pending
        # boundaries stay staged, so a retry re-applies the repartition.
        lay = self.routing.layout(self.epoch)
        if not lay.same_as(self.routing.current_layout):
            self._apply_layout(lay)

    def _publish_epoch(self, epoch: int) -> None:
        # one atomic step: the EpochStore swap, the routing table's
        # epoch -> buffers entry, the epoch's layout (boundaries) AND the
        # epoch's replica buffers (when a plan is active) move together, so
        # the indirection can never resolve an epoch to another epoch's
        # shards or boundaries — and every replica of a shard serves
        # exactly the epoch the primary serves
        super()._publish_epoch(epoch)
        buffers = self._epochs.snapshot(epoch)
        serving = (
            self._build_serving(*buffers) if self._serving_mesh is not None else None
        )
        self.routing.publish(
            epoch, buffers, keep=self._epochs.epochs(), serving=serving
        )
        self._pending_layout = None  # a staged repartition is now live

    def _trim_epoch_stats(self) -> None:
        super()._trim_epoch_stats()
        self.routing.trim(self._epochs.epochs())

    def _table_bytes(self) -> int:
        # the sharded layout pays for the padded rows, count them honestly
        return self.num_shards * (self.shard_rows + 1) * self.k * 8

    # ------------------------------------------------------------------
    # repartition-on-flush: stage new boundaries, apply them inside the
    # next flush's fallible region (the _prepare_publish hook), publish
    # tables + layout in the same atomic _publish_epoch step
    # ------------------------------------------------------------------

    def stage_repartition(self, starts) -> None:
        """Stage new shard-range boundaries for the next flush.

        ``starts`` is a sorted boundary vector (one entry per shard, first
        0, strictly increasing — e.g. from ``propose_starts`` over a query
        histogram). Nothing changes until ``flush_updates``: the flush
        re-lays the working tables under the new boundaries on device and
        publishes tables + layout in one atomic epoch step, so pinned
        reads on older epochs stay bit-identical under their OLD
        boundaries. A flush that fails (or is killed) rolls back to the
        old boundaries with the repartition still staged for the retry.
        """
        lay = ShardLayout.from_starts(self.n, starts)
        if lay.num_shards != self.num_shards:
            raise EngineConfigError(
                f"boundary vector names {lay.num_shards} shards, engine "
                f"has {self.num_shards}"
            )
        self._pending_layout = lay

    def repartition(self, starts) -> dict:
        """``stage_repartition`` + ``flush_updates`` in one call; returns
        the flush stats (any staged object updates ride the same epoch)."""
        self.stage_repartition(starts)
        return self.flush_updates()

    @property
    def pending_repartition(self) -> np.ndarray | None:
        """The staged boundary vector, or None."""
        lay = self._pending_layout
        return None if lay is None else lay.starts.copy()

    def _prepare_publish(self) -> None:
        """Re-lay the working tables under the staged boundaries, on
        device: one gather through the new-layout -> old-layout row map
        (the same move ``shard_tables`` does at build) plus a resharding
        ``device_put``, then swap the host-side layout. Runs inside the
        flush's fallible region — the chaos seam fires ``pre-repartition``
        and ``mid-repartition`` checkpoints, and any failure rolls back
        through ``_restore_tables`` to the old boundaries."""
        lay = self._pending_layout
        if lay is None:
            return
        old = self.routing.current_layout
        if old.same_as(lay):
            self._pending_layout = None
            return
        self._checkpoint("pre-repartition")
        # old-layout source row per new-layout row; pad rows read the old
        # address of the shared dummy vertex n (a pad sentinel row)
        pad_row = int(old.padded_rows(np.array([self.n], np.int64))[0])
        src = np.full(self.num_shards * lay.block, pad_row, np.int64)
        v = np.arange(self.n, dtype=np.int64)
        src[lay.padded_rows(v)] = old.padded_rows(v)
        spec = NamedSharding(self.mesh, P("shard", None))
        src_dev = self._put_repl(src)
        new_ids = jax.device_put(jnp.take(self._ids_g, src_dev, axis=0), spec)
        new_d = jax.device_put(jnp.take(self._d_g, src_dev, axis=0), spec)
        self._checkpoint("mid-repartition")
        self._ids_g, self._d_g = new_ids, new_d
        self._apply_layout(lay)
        self._partition_stats["repartitions"] += 1

    def _apply_layout(self, lay: ShardLayout) -> None:
        """Swap the CURRENT layout: routing boundaries, the vertex ->
        padded-row map, and the device programs for the (possibly new)
        block size. Published epochs keep their own layouts."""
        self.routing.set_layout(lay)
        self.shard_rows = lay.shard_rows
        self._g_of_v = lay.padded_rows(np.arange(self.n, dtype=np.int64))
        self._make_device_fns(self.k)
        # the sharded BNS adjacency is laid out by vertex -> padded-row,
        # so a boundary change invalidates it (rebuilt lazily on the next
        # collective round — under the NEW layout's row map)
        self._nbr_glob_g = None
        if self._serving_mesh is not None:
            self._serving_fns = _device_fns(self._serving_mesh, lay.block, self.k)

    def partition_plan(self) -> PartitionPlan:
        """The active layout as a ``PartitionPlan`` (stats/introspection)."""
        lay = self.routing.current_layout
        rep = tuple(sorted(self.routing.replication.items()))
        return PartitionPlan(
            shards=self.num_shards,
            ranges=None if lay.is_equal else tuple(int(s) for s in lay.starts),
            replication=rep or None,
            policy=self.replica_policy,
        )

    # ------------------------------------------------------------------
    # replicated hot shards: a shard -> extra-replica plan expands the
    # shard set into a slot set served on a wider mesh (primaries on the
    # engine's own devices, replicas on the next free ones). Flushes keep
    # writing only the primary layout; each _publish_epoch re-copies the
    # replicated shards' fresh local blocks onto their replica devices, so
    # replicas are read-only copies refreshed at the swap.
    # ------------------------------------------------------------------

    def set_replication(
        self, plan: dict[int, int] | None, *, policy: str | None = None
    ) -> None:
        """Install (or with ``None``/``{}`` drop) a shard -> extra-replica
        plan and immediately re-publish every retained epoch's replica
        buffers, so pinned reads on any retained epoch can be served from
        replicas too. Raises ``EngineConfigError`` when the visible device
        pool cannot seat ``num_shards + total extras`` slots."""
        if policy is not None:
            if policy not in ("round_robin", "least_outstanding"):
                raise EngineConfigError(
                    f"unknown replica routing policy {policy!r}"
                )
            self.replica_policy = policy
        plan = {int(s): int(r) for s, r in (plan or {}).items() if int(r) > 0}
        if not plan:
            self.routing.set_replication({})
            self._serving_mesh = None
            self._serving_fns = None
            for e in self.routing.epochs():
                self.routing.publish(e, self.routing.buffers(e), serving=None)
            return
        slot_shard = self.routing.set_replication(plan)
        primaries = list(self.mesh.devices.flat)
        extra_pool = [d for d in jax.devices() if d not in primaries]
        extras_needed = len(slot_shard) - self.num_shards
        if extras_needed > len(extra_pool):
            self.routing.set_replication({})
            raise EngineConfigError(
                f"replication plan needs {extras_needed} extra devices beyond "
                f"the {self.num_shards} shard primaries, but only "
                f"{len(extra_pool)} are free (set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count)"
            )
        self._serving_mesh = Mesh(
            np.array(primaries + extra_pool[:extras_needed]), ("shard",)
        )
        self._serving_fns = _device_fns(self._serving_mesh, self.shard_rows + 1, self.k)
        for e in self.routing.epochs():
            buffers = self.routing.buffers(e)
            self.routing.publish(e, buffers, serving=self._build_serving(*buffers))

    def _build_serving(self, ids_g, d_g) -> tuple[jax.Array, jax.Array]:
        """Expand primary-layout global tables into the serving (slot)
        layout: each slot's device gets its logical shard's local (R+1, k)
        block — a no-op reuse for primary slots (the buffer already lives
        there) and one explicit ``jax.device_put`` per replica slot. The
        block size is read off the buffers themselves, so re-publishing an
        epoch that predates a repartition expands under ITS layout."""
        mesh = self._serving_mesh
        block = ids_g.shape[0] // self.num_shards
        slot_shard = self.routing.slot_shard
        spec = NamedSharding(mesh, P("shard", None))
        devs = list(mesh.devices.flat)
        out = []
        for arr in (ids_g, d_g):
            local = {}
            for sh in arr.addressable_shards:
                local[(sh.index[0].start or 0) // block] = sh.data
            bufs = [
                jax.device_put(local[int(s)], d) for s, d in zip(slot_shard, devs)
            ]
            out.append(
                jax.make_array_from_single_device_arrays(
                    (len(slot_shard) * block, arr.shape[1]), spec, bufs
                )
            )
        return tuple(out)

    # ------------------------------------------------------------------
    # device programs (cached per (device set, block, k) at module level —
    # engines built on the same mesh/layout share one jit compile cache, so
    # rebuilding an engine never recompiles; jit then caches per shape)
    # ------------------------------------------------------------------

    def _make_device_fns(self, k: int) -> None:
        fns = _device_fns(self.mesh, self.shard_rows + 1, k)
        self._gather_fn = fns["gather"]
        self._scan_fn = fns["scan"]
        self._purge_fn = fns["purge"]
        self._kth_fn = fns["kth"]
        self._finit_fn = fns["finit"]
        self._fsend_fn = fns["fsend"]
        self._fmin_fn = fns["fmin"]
        self._faff_fn = fns["faff"]
        self._expand_fn = fns["expand"]
        self._rhalo_fn = fns["rhalo"]
        self._fhalo_fn = fns["fhalo"]
        self._fhalo_round_fn = fns["fhalo_round"]

    # ------------------------------------------------------------------
    # explicit host -> mesh uploads. Every operand of the shard_map
    # programs is placed with the exact NamedSharding its in_spec expects,
    # so jit never inserts an implicit device-to-device reshard — which is
    # what the sanitizer's transfer guard (repro.analysis.sanitize) would
    # reject on the query/flush paths.
    # ------------------------------------------------------------------

    def _put_shard(self, x) -> jax.Array:
        """Upload splitting the leading axis across shards."""
        spec = P("shard", *([None] * (np.ndim(x) - 1)))
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def _put_repl(self, x) -> jax.Array:
        """Upload (or re-place) fully replicated across the mesh."""
        return jax.device_put(x, NamedSharding(self.mesh, P()))

    # ------------------------------------------------------------------
    # host-side routing (queries batched per shard, one roundtrip)
    # ------------------------------------------------------------------

    def _group_by_owner(
        self, owner: np.ndarray, groups: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Stable group-by-owner used by query routing (``groups`` = shard
        count, or slot count on the replicated serving path) and the
        flush's row batching: (input order permutation, owner per sorted
        entry, slot within the owner's group, max group size)."""
        if groups is None:
            groups = self.num_shards
        order = np.argsort(owner, kind="stable")
        counts = np.bincount(owner, minlength=groups)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        o_sorted = owner[order]
        slot = np.arange(len(owner)) - starts[o_sorted]
        return order, o_sorted, slot, int(counts.max()) if len(owner) else 1

    def _route(
        self, vs: np.ndarray, layout: ShardLayout | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Group vertices by owner shard: ((S, Bmax) global padded rows with
        -1 padding, (B,) flat result positions restoring the input order).
        ``layout`` defaults to the CURRENT boundaries; a pinned read on an
        epoch published before a repartition passes that epoch's layout.

        Out-of-range ids get the scalar gather's jnp indexing semantics, so
        the bit-identical contract holds even for garbage queries: negative
        ids wrap once from the end of the (n+1)-row table (so -1 is the
        dummy row -> pad sentinel), everything still outside clamps into
        [0, n], and ids >= n read a dummy row -> pad sentinel (-1, +inf).
        """
        if layout is None:
            layout = self.routing.current_layout
        vs = np.asarray(vs, np.int64)
        vs = np.where(vs < 0, vs + self.n + 1, vs)  # jnp negative wraparound
        vs = np.clip(vs, 0, self.n)                 # then the XLA gather clamp
        oob = vs >= self.n
        owner = layout.owner(vs)
        order, o_sorted, slot, bmax = self._group_by_owner(owner)
        bmax = _pow2_pad(bmax, lo=8)
        qglob = np.full((self.num_shards, bmax), -1, np.int32)
        qglob[o_sorted, slot] = np.where(
            oob[order], -1, layout.padded_rows(vs[order], o_sorted)
        )
        fidx = np.empty(len(vs), dtype=np.int64)
        fidx[order] = o_sorted * bmax + slot
        return qglob, fidx

    def _route_slots(
        self, vs: np.ndarray, layout: ShardLayout | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Replicated-path analogue of ``_route``: group vertices by
        serving *slot* (shard or replica, per the routing policy) into the
        ((V, Bmax) serving-layout padded rows, (B,) flat result positions,
        (B,) chosen slots) triple. Same wraparound/clamp semantics as
        ``_route``, and every slot serves byte-identical buffers — so the
        results stay bit-identical to the unreplicated gather no matter
        which replica each query lands on."""
        if layout is None:
            layout = self.routing.current_layout
        vs = np.asarray(vs, np.int64)
        vs = np.where(vs < 0, vs + self.n + 1, vs)  # jnp negative wraparound
        vs = np.clip(vs, 0, self.n)                 # then the XLA gather clamp
        oob = vs >= self.n
        own = layout.owner(vs)
        slots = self.routing.assign_slots(own, self.replica_policy)
        nslots = self.routing.num_slots
        order, s_sorted, pos, bmax = self._group_by_owner(slots, groups=nslots)
        bmax = _pow2_pad(bmax, lo=8)
        rows = layout.serving_rows(vs, own, slots)
        qglob = np.full((nslots, bmax), -1, np.int32)
        qglob[s_sorted, pos] = np.where(oob[order], -1, rows[order])
        fidx = np.empty(len(vs), dtype=np.int64)
        fidx[order] = s_sorted * bmax + pos
        return qglob, fidx, slots

    def _consolidate(self, x: jax.Array) -> np.ndarray:
        """Sharded tile -> pooled host buffer (one memcpy per shard).

        ``np.asarray`` on a multi-MB tile allocates a fresh mmap'd buffer
        every call, and the page-fault churn is bimodal across processes —
        enough to flap the exp16 floor. Copying through a reused staging
        buffer (zero-copy dlpack view of each shard, two rotating buffers
        per shape so the bytes a just-dispatched ``device_put`` reads are
        never overwritten by the next batch) keeps the copy on the warm
        memcpy path."""
        key = (x.shape, str(x.dtype))
        pair = self._cons_bufs.get(key)
        if pair is None:
            pair = self._cons_bufs.setdefault(
                key, [np.empty(x.shape, x.dtype), np.empty(x.shape, x.dtype), 0]
            )
        buf = pair[pair[2]]
        pair[2] ^= 1
        for j, sh in enumerate(x.addressable_shards):
            np.copyto(buf[j], np.from_dlpack(sh.data)[0])
        return buf

    def _gather_replicated(
        self, us: np.ndarray, ks: jax.Array, serving: tuple,
        layout: ShardLayout | None = None,
    ):
        """Two-phase gather over the serving (slot) layout: the shard_map
        tile program on the wider replica mesh (hot shard's queries fanned
        out across its slot set), then one explicit consolidation onto the
        lead device where the batch-order epilogue runs exactly once —
        rather than replicated per slot, which would grow the epilogue cost
        with every replica added."""
        if self.replica_fault_hook is not None:
            self.replica_fault_hook(self)  # chaos seam: simulated replica loss
        if layout is None:
            layout = self.routing.current_layout
        s_ids, s_d = serving
        qglob, fidx, slots = self._route_slots(us, layout)
        mesh = self._serving_mesh
        fns = (
            self._serving_fns
            if layout.same_as(self.routing.current_layout)
            else _device_fns(mesh, layout.block, self.k)
        )
        lead = SingleDeviceSharding(mesh.devices.flat[0])
        self.routing.record_dispatch(slots)
        try:
            gi, gd = fns["gather_tile"](
                s_ids, s_d,
                jax.device_put(qglob, NamedSharding(mesh, P("shard", None))),
            )
            # consolidate through pooled host staging buffers: an explicit
            # readback + upload both take the plain memcpy path, where the
            # direct sharded->single-device device_put of a multi-MB tile
            # lands on a slow generic copy often enough to flap the exp16
            # floor
            out = fns["gather_epi"](
                jax.device_put(self._consolidate(gi), lead),
                jax.device_put(self._consolidate(gd), lead),
                jax.device_put(fidx, lead), jax.device_put(ks, lead),
            )
        finally:
            self.routing.record_complete(slots)
        self._rstats["replica_batches"] += 1
        self._rstats["replica_queries"] += int(np.sum(slots >= self.num_shards))
        return out

    def _gather_batch(self, us: np.ndarray, ks: jax.Array, snap: tuple, epoch: int):
        # resolve the epoch's OWN layout: after a repartition, a pinned
        # read on an old epoch routes by the boundaries it was published
        # with (and runs the matching block-size gather program)
        layout = self.routing.layout(epoch)
        serving = self.routing.serving(epoch)
        if serving is not None and self._serving_fns is not None:
            try:
                return self._gather_replicated(us, ks, serving, layout)
            except QueryError:
                raise  # routing misuse, not a replica fault
            except Exception as e:  # noqa: BLE001 — degrade, don't die
                self._rstats["replica_errors"] += 1
                self._rstats["last_replica_error"] = f"{type(e).__name__}: {e}"
        ids_g, d_g = snap
        if self.num_shards == 1:
            # one shard: the global layout IS the scalar (n+1, k) layout and
            # routing is the identity, so serve through the scalar gather
            # (same jitted program the plain engine runs — 1-shard parity)
            return ops.serve_gather(ids_g, d_g, jnp.asarray(us), ks)
        qglob, fidx = self._route(us, layout)
        fns = _device_fns(self.mesh, layout.block, self.k)
        if len(us) >= 4096 and qglob.size <= 2 * len(us):
            # Balanced tile (Bmax ~ B/S, e.g. traffic-balanced uneven
            # ranges, or equal-width under uniform traffic): consolidate
            # the sharded tile onto the lead device and run the
            # batch-order epilogue exactly once — the same two-phase split
            # the replica fan-out path uses. The one-jit form below pays
            # its epilogue per device, which swamps the tile savings. A
            # skew-padded tile (Bmax -> B, so S*Bmax >> B) flips the
            # trade: consolidating S*Bmax rows costs more than the
            # replicated epilogue, so the rectangle stays on the one-jit
            # path.
            lead = SingleDeviceSharding(self.mesh.devices.flat[0])
            gi, gd = fns["gather_tile"](ids_g, d_g, self._put_shard(qglob))
            return fns["gather_epi"](
                jax.device_put(self._consolidate(gi), lead),
                jax.device_put(self._consolidate(gd), lead),
                jax.device_put(fidx, lead), jax.device_put(ks, lead),
            )
        return fns["gather"](
            ids_g, d_g, self._put_shard(qglob), self._put_repl(fidx),
            self._put_repl(ks),
        )

    def _fetch_rows(self, vs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Routed raw-row fetch (host result) for the repair halo exchange.

        The fetch count is pow2-padded (duplicate fetches of vertex 0 are
        free) so the gather's jit signature set stays bounded even though
        every repair round asks for a different number of halo rows.
        """
        m = len(vs)
        m_pad = _pow2_pad(m, lo=64)
        vs_p = np.zeros(m_pad, np.int32)
        vs_p[:m] = vs
        qglob, fidx = self._route(vs_p)
        ks = self._put_repl(np.full((m_pad,), self.k, np.int32))
        gi, gd = self._gather_fn(
            self._ids_g, self._d_g, self._put_shard(qglob), self._put_repl(fidx), ks
        )
        return np.asarray(gi)[:m], np.asarray(gd)[:m]

    # ------------------------------------------------------------------
    # flush hooks (per-shard application)
    # ------------------------------------------------------------------

    def _scan_delete_rows(self, deletes: list[int]) -> np.ndarray:
        del_arr = self._put_repl(self._padded_deletes(deletes))
        # (S, shard_rows) per-shard hit masks: local row j of shard s is
        # vertex starts[s] + j while j < widths[s] (rows past a shard's
        # range width are all-pad under uneven ranges, never hit — but the
        # map back to vertex ids must still go through the boundaries)
        hits = np.asarray(self._scan_fn(self._ids_g, del_arr))
        hits = hits.reshape(self.num_shards, -1)
        lay = self.routing.current_layout
        s_idx, j_idx = np.nonzero(hits)
        valid = j_idx < lay.widths[s_idx]
        return (lay.starts[s_idx] + j_idx)[valid].astype(np.int32)

    def _table_kth(self) -> np.ndarray:
        kth = np.asarray(self._kth_fn(self._d_g))
        return kth[self._g_of_v].astype(np.float64)

    def _apply_rows(
        self, rows: np.ndarray, deletes: list[int],
        cand_ids: np.ndarray, cand_d: np.ndarray,
    ) -> np.ndarray:
        """Split a global row batch by owner shard and run the per-shard
        fused purge+merge; returns the per-row changed mask (input order)."""
        s = self.num_shards
        b = len(rows)
        order, o_sorted, slot, rmax = self._group_by_owner(self.routing.owner(rows))
        rmax = _pow2_pad(rmax, lo=16)
        p = cand_ids.shape[1]
        rglob = np.full((s, rmax), -1, np.int32)
        ci = np.full((s, rmax, p), -1, np.int32)
        cd = np.full((s, rmax, p), np.inf, np.float32)
        rglob[o_sorted, slot] = self.routing.padded_rows(rows[order], o_sorted)
        ci[o_sorted, slot] = cand_ids[order]
        cd[o_sorted, slot] = cand_d[order]
        self._ids_g, self._d_g, changed = self._purge_fn(
            self._ids_g, self._d_g, self._put_shard(rglob),
            self._put_repl(self._padded_deletes(deletes)),
            self._put_shard(ci), self._put_shard(cd),
        )
        changed = np.asarray(changed)
        out = np.zeros(b, dtype=bool)
        out[order] = changed[o_sorted, slot]
        return out

    def _purge_merge(self, rows, deletes, cand_ids, cand_d) -> None:
        self._apply_rows(rows, deletes, cand_ids, cand_d)

    def _repair_part(self, part: np.ndarray) -> np.ndarray:
        """One Jacobi re-merge of ``part`` against its bridge neighborhoods.

        At one shard there is no boundary to exchange across — every
        neighbor row is local — so the round degenerates to the scalar
        engine's device-resident repair (the 1-shard global layout IS the
        scalar (n+1, k) layout), sharing its jitted program; that is what
        keeps the exp13 single-shard parity floor honest. Multi-shard, the
        cross-shard halo runs per ``self.halo``: the collective all_gather
        round (overflow falls back for this round), or the routed-gather
        baseline. Identical candidate multisets to the scalar engine's
        repair round either way, so the merged rows are bit-identical.
        """
        if self.num_shards == 1:
            from repro.core.engine import _repair_round

            nbr_tab, w_tab = self._nbr_slice(self._t_bucket(part))
            self._ids_g, self._d_g, changed = _repair_round(
                nbr_tab, w_tab, self._pad_rows(part), self._ids_g, self._d_g
            )
            return np.asarray(changed)
        if self.halo == "collective":
            out = self._repair_part_collective(part)
            if out is not None:
                return out
            self._halo_stats["halo_fallbacks"] += 1
        return self._repair_part_host(part)

    def _repair_part_host(self, part: np.ndarray) -> np.ndarray:
        """Routed-gather repair round: fetch the unique neighbor rows
        (cross-shard halo, one routed gather through the host), build the
        shifted candidate lists on host, apply the shard-local merge."""
        k = self.k
        t = self._t_bucket(part)
        nbr = self._nbr_ids[part, :t]
        w = self._nbr_w[part, :t]
        valid = nbr >= 0
        uniq, inv = np.unique(nbr[valid], return_inverse=True)
        f_ids, f_d = self._fetch_rows(uniq)
        f_ids = np.concatenate([f_ids, np.full((1, k), -1, np.int32)])
        f_d = np.concatenate([f_d, np.full((1, k), np.inf, np.float32)])
        slot_idx = np.full(nbr.shape, len(uniq), dtype=np.int64)
        slot_idx[valid] = inv
        g_ids = f_ids[slot_idx]                    # (B, t, k)
        g_d = w[..., None] + f_d[slot_idx]         # float32 + float32
        cand_ids = g_ids.reshape(len(part), t * k)
        cand_d = g_d.reshape(len(part), t * k).astype(np.float32)
        cand_d = np.where(cand_ids < 0, np.float32(np.inf), cand_d)
        return self._apply_rows(part, [], cand_ids, cand_d)

    def _repair_part_collective(self, part: np.ndarray) -> np.ndarray | None:
        """Collective repair round: one fused rhalo program (serve rows,
        all_gather, purge+merge) — the rows never visit the host. Returns
        None when the round's halo exceeds ``halo_capacity`` (the caller
        falls back to the routed path for this round)."""
        t = self._t_bucket(part)
        plan = self._halo_plan(part, self._nbr_ids[part, :t], self._nbr_w[part, :t])
        if plan is None:
            return None
        serve, slotm, wm, rglob, order, o_sorted, slot = plan
        self._ids_g, self._d_g, changed = self._rhalo_fn(
            self._ids_g, self._d_g, self._put_shard(serve),
            self._put_shard(slotm), self._put_shard(wm),
            self._put_shard(rglob), self._put_repl(self._padded_deletes([])),
        )
        self._halo_stats["halo_rounds_collective"] += 1
        changed = np.asarray(changed)
        out = np.zeros(len(part), dtype=bool)
        out[order] = changed[o_sorted, slot]
        return out

    def _halo_plan(self, part: np.ndarray, nbr: np.ndarray, w: np.ndarray):
        """Index bookkeeping for one collective halo round (repair or
        frontier): which unique neighbor rows each owner serves, and where
        each receiver finds its neighbors in the all_gather receive
        buffer.

        Returns ``(serve, slotm, wm, rglob, order, o_sorted, slot)`` or
        None when the padded per-owner served-row count exceeds
        ``halo_capacity``:

        - ``serve`` (S, Umax): global padded rows shard *src* serves
          (-1 pads) — every unique neighbor of ``part`` appears exactly
          once, in its owner's slice (multicast: receivers on every shard
          read the same served copy);
        - ``slotm`` (S, rmax, t): per-receiver position of each neighbor
          in the flattened (S*Umax) receive buffer (S*Umax = miss, which
          the device fold/candidate ops mask to (-1, +inf));
        - ``wm``    (S, rmax, t) edge weights, ``rglob`` (S, rmax) global
          receiver rows (-1 pads), both in the grouped-by-owner layout;
        - ``order/o_sorted/slot``: the group-by-owner permutation that
          maps the grouped changed-mask back to ``part`` order.

        Every row map goes through the CURRENT epoch's ``ShardLayout``
        (``owner`` / ``padded_rows``) — never flat ``vertex // block``
        arithmetic — so uneven ranges and live repartitions route the halo
        exactly like queries and deletes.
        """
        lay = self.routing.current_layout
        s = self.num_shards
        t = nbr.shape[1]
        valid = nbr >= 0
        uniq, inv = np.unique(nbr[valid], return_inverse=True)
        own_u = lay.owner(uniq)
        order_u, src_sorted, within, umax = self._group_by_owner(own_u)
        umax = _pow2_pad(umax, lo=16)
        if umax > self.halo_capacity:
            return None
        serve = np.full((s, umax), -1, np.int32)
        serve[src_sorted, within] = lay.padded_rows(uniq[order_u], src_sorted)
        pos = np.empty(len(uniq), np.int64)
        pos[order_u] = src_sorted * umax + within
        sm = np.full(nbr.shape, s * umax, np.int64)
        sm[valid] = pos[inv]
        order, o_sorted, slot, rmax = self._group_by_owner(lay.owner(part))
        rmax = _pow2_pad(rmax, lo=16)
        slotm = np.full((s, rmax, t), s * umax, np.int32)
        wm = np.zeros((s, rmax, t), np.float32)
        rglob = np.full((s, rmax), -1, np.int32)
        slotm[o_sorted, slot] = sm[order]
        wm[o_sorted, slot] = w[order]
        rglob[o_sorted, slot] = lay.padded_rows(part[order], o_sorted)
        return serve, slotm, wm, rglob, order, o_sorted, slot

    def _nbr_glob(self) -> jax.Array:
        """The sharded (S*(R+1), cap) BNS adjacency in the CURRENT row
        layout (vertex v's padded neighbor ids at row ``_g_of_v[v]``, all
        ``-1`` on pad rows), built lazily and dropped by ``_apply_layout``
        so the device expansion can never gather through stale boundaries."""
        if self._nbr_glob_g is None:
            self._nbr_tables()
            rows = self.num_shards * (self.shard_rows + 1)
            self._nbr_glob_g = self._put_shard(
                self.bn.bns_packed().relayout_rows(rows, self._g_of_v)
            )
        return self._nbr_glob_g

    def _expand_receivers(self, active: np.ndarray) -> np.ndarray:
        if self.num_shards == 1 or self.halo != "collective":
            return super()._expand_receivers(active)
        # if the previous frontier round ran fully collective, its fhalo
        # programs already psum'd this round's presence mask (neighbors of
        # exactly the changed = active rows) — read those instead of
        # dispatching a standalone expansion
        masks, ok = self._fmask, self._fmask_ok
        self._fmask, self._fmask_ok = [], True  # arm for the coming round
        if masks and ok:
            m = np.sum([np.asarray(x)[:-1] for x in masks], axis=0)
            return np.flatnonzero(m).astype(np.int32)
        return self._expand_receivers_device(active)

    def _expand_receivers_device(self, active: np.ndarray) -> np.ndarray:
        """Device receiver-set expansion: route the active vertices to
        their owners, scatter their padded BNS rows into a psum'd presence
        mask on device, read back the mask and flatnonzero it — ascending
        unique. Exactly ``np.unique`` of the host CSR expansion — pinned
        by test."""
        aglob, _ = self._route(active)
        mask = np.asarray(self._expand_fn(self._nbr_glob(), self._put_shard(aglob)))
        return np.flatnonzero(mask[:-1]).astype(np.int32)

    def _repair_receivers(
        self, changed: np.ndarray, rows: np.ndarray
    ) -> np.ndarray:
        if self.num_shards == 1 or self.halo != "collective":
            return super()._repair_receivers(changed, rows)
        self._nbr_tables()
        return np.intersect1d(
            self._expand_receivers_device(changed), rows
        ).astype(np.int32)

    # ------------------------------------------------------------------
    # frontier provider (shard-local checkIns)
    # ------------------------------------------------------------------

    def _frontier_init(self, src: np.ndarray):
        self._fmask, self._fmask_ok = None, True  # round 1 expands standalone
        srcp = self._frontier_pad_src(src)
        self._fsrc = jnp.asarray(srcp)  # vertex ids (the 1-shard scalar path)
        grow = np.full(srcp.shape, -1, np.int64)
        m = srcp >= 0
        grow[m] = self._g_of_v[srcp[m]]
        self._fsrc_g = self._put_repl(grow.astype(np.int32))
        if self.num_shards == 1:
            from repro.core.engine import _frontier_init_prog

            return _frontier_init_prog(self._fsrc, self._ids_g.shape[0])
        return self._finit_fn(self._fsrc_g)

    def _frontier_part(self, state, part: np.ndarray):
        """One shard-local frontier round over one receiver bucket.

        At one shard every neighbor row is local and the global layout IS
        the scalar (n+1, B) layout, so the round degenerates to the scalar
        engine's device-resident program (shared jit cache, exp14 parity).
        Multi-shard, the cross-shard halo runs per ``self.halo`` — the
        fused collective fhalo round (overflow falls back for this round)
        or the routed-gather baseline below. Identical candidate values to
        the scalar engine's ``ops.frontier_relax`` round either way, so
        the dist trajectories — and hence the affected sets and candidate
        distances — are bit-identical.
        """
        if self.num_shards == 1:
            from repro.core.engine import _frontier_round

            nbr_tab, w_tab = self._nbr_slice(self._t_bucket(part))
            state, changed = _frontier_round(
                nbr_tab, w_tab, self._pad_rows(part), state, self._d_g,
                self._fsrc, self.use_pallas,
            )
            return state, np.asarray(changed)
        if self.halo == "collective":
            out = self._frontier_part_collective(state, part)
            if out is not None:
                return out
            self._halo_stats["halo_fallbacks"] += 1
        # a routed part contributes nothing to the fused presence mask, so
        # the round's expansion must run standalone
        self._fmask_ok = False
        return self._frontier_part_host(state, part)

    def _frontier_part_collective(self, state, part: np.ndarray):
        """Collective frontier round: one fused fhalo program (gate,
        all_gather, min-fold, min-update) — gated distance rows move
        shard-to-shard without visiting the host. Returns None on capacity
        overflow (the caller falls back to the routed path for this
        round). The changed mask comes back as a thunk: the device value
        is only read when the round closes, so the plan/upload work for
        the round's remaining buckets overlaps the device compute instead
        of stalling on a per-part readback."""
        t = self._t_bucket(part)
        plan = self._halo_plan(part, self._nbr_ids[part, :t], self._nbr_w[part, :t])
        if plan is None:
            return None
        serve, slotm, wm, rglob, order, o_sorted, slot = plan
        state, changed, nmask = self._fhalo_fn(
            self._nbr_glob(), self._d_g, state, self._put_shard(serve),
            self._put_shard(slotm), self._put_shard(wm),
            self._put_shard(rglob), self._fsrc_g,
        )
        if self._fmask is not None:
            self._fmask.append(nmask)
        self._halo_stats["halo_rounds_collective"] += 1

        def resolve(changed=changed, order=order, o_sorted=o_sorted, slot=slot):
            cm = np.asarray(changed)
            out = np.zeros(len(part), dtype=bool)
            out[order] = cm[o_sorted, slot]
            return out

        return state, resolve

    def _frontier_round(self, state, nbrs: np.ndarray):
        if self.num_shards == 1 or self.halo != "collective":
            return super()._frontier_round(state, nbrs)
        out = self._frontier_round_collective(state, nbrs)
        if out is not None:
            return out
        self._halo_stats["halo_fallbacks"] += 1
        # the fused round overflowed halo_capacity: re-run bucketed (each
        # part retries the per-part collective program, then the routed
        # host path), and let the round's expansion run standalone
        self._fmask_ok = False
        return super()._frontier_round(state, nbrs)

    def _frontier_round_collective(self, state, nbrs: np.ndarray):
        """One fused collective frontier round: a single fhalo_round
        program runs every degree bucket's gate/all_gather/fold/min-update
        back to back, each bucket over its own ``_halo_plan`` serve slab.
        Returns None when any bucket's serve set overflows
        ``halo_capacity`` (the caller falls back to the bucketed path).
        The state threads bucket-to-bucket inside the program — the same
        sequential schedule as the per-part paths — so the round
        trajectories, not just the fixpoint, match the scalar engine."""
        parts = list(self._bucket_parts(nbrs))
        if not parts:
            return state, []
        serves, slots, wms, rglobs, maps = [], [], [], [], []
        for part in parts:
            t = self._t_bucket(part)
            plan = self._halo_plan(
                part, self._nbr_ids[part, :t], self._nbr_w[part, :t]
            )
            if plan is None:
                return None
            serve, slotm, wm, rglob, order, o_sorted, slot = plan
            serves.append(self._put_shard(serve))
            slots.append(self._put_shard(slotm))
            wms.append(self._put_shard(wm))
            rglobs.append(self._put_shard(rglob))
            maps.append((part, order, o_sorted, slot))
        state, chs, nmask = self._fhalo_round_fn(
            self._nbr_glob(), self._d_g, state, self._fsrc_g,
            serves, slots, wms, rglobs,
        )
        if self._fmask is not None:
            self._fmask.append(nmask)
        self._halo_stats["halo_rounds_collective"] += len(parts)
        changed_parts = []
        for ch, (part, order, o_sorted, slot) in zip(chs, maps):
            cm = np.asarray(ch)
            out = np.zeros(len(part), dtype=bool)
            out[order] = cm[o_sorted, slot]
            changed_parts.append(part[out])
        return state, changed_parts

    def _frontier_part_host(self, state, part: np.ndarray):
        """Routed-gather frontier round: fetch the gated neighbor send
        rows (cross-shard halo, one routed gather through the host — the
        owner applies the checkIns gate before its tentative distances
        leave the shard, so the k-th column itself never moves), fold the
        edge shift + min over neighbors on host, apply the per-shard
        min-update."""
        t = self._t_bucket(part)
        nbr = self._nbr_ids[part, :t]
        w = self._nbr_w[part, :t]
        valid = nbr >= 0
        uniq, inv = np.unique(nbr[valid], return_inverse=True)
        send = self._fetch_send(state, uniq)               # (U, B) float32
        b = send.shape[1]
        send = np.concatenate([send, np.full((1, b), np.inf, np.float32)])
        slot = np.full(nbr.shape, len(uniq), dtype=np.int64)
        slot[valid] = inv
        # fold the min over the neighbor columns one at a time — (P, B)
        # intermediates, never the (P, t, B) candidate tensor (the same
        # memory discipline as ops.frontier_relax's fori_loop form; min is
        # fold-order-insensitive, so the values stay bit-identical)
        cand = np.full((len(part), b), np.inf, np.float32)
        for j in range(t):
            np.minimum(cand, w[:, j, None] + send[slot[:, j]], out=cand)
        return self._apply_fmin(state, part, cand)

    def _fetch_send(self, state, vs: np.ndarray) -> np.ndarray:
        """Routed gated-row fetch (host result) for the frontier halo.

        pow2-padded fetch count, same signature-bounding trick as
        ``_fetch_rows`` (duplicate fetches of vertex 0 are free)."""
        m = len(vs)
        m_pad = _pow2_pad(m, lo=64)
        vs_p = np.zeros(m_pad, np.int32)
        vs_p[:m] = vs
        qglob, fidx = self._route(vs_p)
        out = self._fsend_fn(
            self._d_g, state, self._put_shard(qglob), self._put_repl(fidx),
            self._fsrc_g,
        )
        return np.asarray(out)[:m]

    def _apply_fmin(self, state, rows: np.ndarray, vals: np.ndarray):
        """Split a receiver batch by owner shard and run the per-shard
        min-update; returns (new state, per-row changed mask) with the mask
        reordered back to the caller's row order."""
        s = self.num_shards
        order, o_sorted, slot, rmax = self._group_by_owner(self.routing.owner(rows))
        rmax = _pow2_pad(rmax, lo=16)
        b = vals.shape[1]
        rglob = np.full((s, rmax), -1, np.int32)
        vv = np.full((s, rmax, b), np.inf, np.float32)
        rglob[o_sorted, slot] = self.routing.padded_rows(rows[order], o_sorted)
        vv[o_sorted, slot] = vals[order]
        state, changed = self._fmin_fn(
            state, self._put_shard(rglob), self._put_shard(vv)
        )
        changed = np.asarray(changed)
        out = np.zeros(len(rows), dtype=bool)
        out[order] = changed[o_sorted, slot]
        return state, out

    def _frontier_extract(self, state, rows: np.ndarray, src: np.ndarray):
        if self.num_shards == 1:
            from repro.core.engine import _frontier_affected

            aff, d = _frontier_affected(
                self._pad_rows(rows), state, self._d_g, self._fsrc
            )
            return (
                np.asarray(aff)[: len(rows), : len(src)],
                np.asarray(d)[: len(rows), : len(src)],
            )
        m = len(rows)
        m_pad = _pow2_pad(m, lo=64)
        vs_p = np.zeros(m_pad, np.int32)
        vs_p[:m] = rows
        qglob, fidx = self._route(vs_p)
        aff, d = self._faff_fn(
            self._d_g, state, self._put_shard(qglob), self._put_repl(fidx),
            self._fsrc_g,
        )
        return np.asarray(aff)[:m, : len(src)], np.asarray(d)[:m, : len(src)]

    # ------------------------------------------------------------------
    # persistence / stats
    # ------------------------------------------------------------------

    def _host_tables(self) -> tuple[np.ndarray, np.ndarray]:
        # always the logical vertex-order (n, k) layout: shard padding is a
        # runtime concern, not an artifact concern (enables reshard-on-load)
        return (
            np.asarray(self._ids_g)[self._g_of_v],
            np.asarray(self._d_g)[self._g_of_v],
        )

    def _save_meta(self) -> dict:
        meta = {"shards": self.num_shards, "shard_rows": self.shard_rows}
        lay = self.routing.current_layout
        if not lay.is_equal:
            # uneven boundaries persist with the artifact; load re-applies
            # them when the reader keeps the writer's shard count
            meta["starts"] = [int(s) for s in lay.starts]
        if self.routing.replication:
            # the plan is keyed by shard id, so it only transfers to a
            # reader at the same shard count (load re-applies or drops it)
            meta["replication"] = {
                str(s): r for s, r in self.routing.replication.items()
            }
        return meta

    def _extra_stats(self) -> dict:
        padded = self.num_shards * (self.shard_rows + 1)
        lay = self.routing.current_layout
        return {
            "num_shards": self.num_shards,
            "shard_rows": self.shard_rows,
            "padded_rows": padded,
            "row_padding_overhead": round((padded - self.n) / max(self.n, 1), 4),
            "shard_starts": [int(s) for s in lay.starts],
            "range_rows": [int(w) for w in lay.widths],
            "uneven_ranges": not lay.is_equal,
            "repartitions": self._partition_stats["repartitions"],
            "halo": self.halo,
            **self._halo_stats,
            "replication": dict(self.routing.replication),
            "replica_slots": self.routing.num_slots,
            "replica_policy": self.replica_policy,
            **self._rstats,
        }
