"""Candidate-object update maintenance — Algorithms 4 (insert) and 5 (delete),
plus their composition ``move_object`` (the moving-objects workload primitive).

Both propagate from the updated object u over BNS edges, pruned by the current
k-th distance of each visited vertex (checkIns / checkDel). We use a distance-
ordered frontier (lazy-deletion heap) rather than the paper's FIFO queue: it
explores the same pruned region but guarantees dist[v] is settled exactly when
v is expanded, which is the invariant the paper's Theorems 6.2/6.4 assert.

This module is the scalar *host reference oracle*: one update at a time
against the numpy ``KNNIndex``. The production path is the batched,
device-resident staged-update queue of ``repro.core.engine.QueryEngine``,
which is property-tested to be ``indices_equivalent`` to a sequential replay
through these functions. ``insert_affected_set`` is shared: the engine runs
the same checkIns frontier against its k-th-distance mirror.
"""
from __future__ import annotations

import heapq
from typing import Callable

import numpy as np

from repro.core.bngraph import BNGraph
from repro.core.index import PAD_DIST, PAD_ID, KNNIndex


def _kth_dist(index: KNNIndex, v: int) -> float:
    """Distance of v's current k-th nearest object (+inf if the row is short)."""
    row = index.dists[v]
    if index.ids[v, -1] == PAD_ID:
        return np.inf
    return float(row[-1])


def insert_affected_set(
    bn: BNGraph, kth_of: Callable[[int], float], u: int
) -> dict[int, float]:
    """checkIns frontier search (Algorithm 4 lines 1-8): the set S of vertices
    whose V_k the insertion of u changes, with exact dist(u, v) for each.

    ``kth_of(v)`` must return v's current k-th nearest distance (+inf when the
    row is short); both the scalar oracle and the batched engine call through
    here so their pruned regions coincide.
    """
    dist: dict[int, float] = {u: 0.0}
    settled: set[int] = set()
    affected: dict[int, float] = {}
    heap: list[tuple[float, int]] = [(0.0, u)]
    while heap:
        d, w = heapq.heappop(heap)
        if w in settled or d > dist.get(w, np.inf):
            continue
        settled.add(w)
        if not (d < kth_of(w) or w == u):  # checkIns
            continue  # V_k(w) unaffected -> propagation stops here (Lemma 6.1)
        affected[w] = d
        for v, phi in bn.bns(w):
            nd = d + phi
            if nd < dist.get(v, np.inf):
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return affected


def _affected_set(
    bn: BNGraph, index: KNNIndex, u: int, *, for_delete: bool
) -> dict[int, float]:
    """Shared frontier search of Algorithms 4/5 (lines 1-8): the set S of
    vertices whose V_k may change, with exact dist(u, v) for each."""
    if not for_delete:
        return insert_affected_set(bn, lambda v: _kth_dist(index, v), u)
    dist: dict[int, float] = {u: 0.0}
    settled: set[int] = set()
    affected: dict[int, float] = {}
    heap: list[tuple[float, int]] = [(0.0, u)]
    while heap:
        d, w = heapq.heappop(heap)
        if w in settled or d > dist.get(w, np.inf):
            continue
        settled.add(w)
        in_row = bool(np.any(index.ids[w] == u))
        if not (in_row and d <= _kth_dist(index, w)):  # checkDel
            continue  # V_k(w) unaffected -> propagation stops here (Lemma 6.1)
        affected[w] = d
        for v, phi in bn.bns(w):
            nd = d + phi
            if nd < dist.get(v, np.inf):
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return affected


def insert_object(bn: BNGraph, index: KNNIndex, u: int) -> int:
    """Algorithm 4: insert object u; returns |S| (the paper's Delta)."""
    affected = _affected_set(bn, index, u, for_delete=False)
    for v, d in affected.items():
        row_ids, row_d = index.ids[v], index.dists[v]
        # lines 9-10: drop v_k, insert (u, d) at its sorted position.
        pos = int(np.searchsorted(row_d, d, side="right"))
        if pos >= index.k:
            continue
        row_ids[pos + 1 :] = row_ids[pos:-1]
        row_d[pos + 1 :] = row_d[pos:-1]
        row_ids[pos] = u
        row_d[pos] = d
    return len(affected)


def move_object(bn: BNGraph, index: KNNIndex, u: int, v: int) -> int:
    """Object movement: the object at vertex u relocates to vertex v.

    The scalar host oracle for ``QueryEngine.stage_move``: Algorithm 4 at the
    destination followed by Algorithm 5 at the source. Insertion runs first
    so rows never go transiently deficient — the final index is a pure
    function of the object set (Theorems 6.2/6.4), so the order only affects
    intermediate states. The caller guarantees u is an object and v is not
    (same contract as insert_object/delete_object). Returns the total |S|
    over both halves.
    """
    if u == v:
        raise ValueError(f"move source and destination are both {u}")
    delta = insert_object(bn, index, v)
    return delta + delete_object(bn, index, u)


def delete_object(bn: BNGraph, index: KNNIndex, u: int) -> int:
    """Algorithm 5: delete object u; returns |S|.

    processDel (lines 15-18) finds the replacement from neighbors' lists. We
    run the decreasing-rank pass to a fixpoint: a second pass is needed when a
    replacement's shortest path runs through a *lower*-ranked neighbor whose
    own row was repaired after v's (the paper's single pass leaves this case
    implicit); the loop almost always converges in one pass.
    """
    affected = _affected_set(bn, index, u, for_delete=True)
    order = sorted(affected, key=lambda v: -int(bn.rank[v]))
    # Remove u everywhere first so stale entries never act as candidates.
    for v in order:
        row_ids, row_d = index.ids[v], index.dists[v]
        keep = row_ids != u
        nk = int(keep.sum())
        index.ids[v, :nk] = row_ids[keep]
        index.dists[v, :nk] = row_d[keep]
        index.ids[v, nk:] = PAD_ID
        index.dists[v, nk:] = PAD_DIST
    # processDel to fixpoint: tentative replacement per deficient row, refined
    # until stable (replacement distances only ever decrease -> terminates).
    repl: dict[int, tuple[int, float]] = {}
    deficient = [v for v in order if index.ids[v, -1] == PAD_ID]
    present_sets = {
        v: set(index.ids[v][index.ids[v] != PAD_ID].tolist()) for v in deficient
    }
    changed = True
    while changed:
        changed = False
        for v in deficient:
            present = present_sets[v]
            best_id, best_d = repl.get(v, (PAD_ID, np.inf))
            for w, phi in bn.bns(v):
                for j in range(index.k):
                    cid = int(index.ids[w, j])
                    if cid == PAD_ID:
                        break
                    if cid in present:
                        continue
                    nd = phi + float(index.dists[w, j])
                    if nd < best_d:
                        best_id, best_d = cid, nd
                rw = repl.get(w)
                if rw is not None and rw[0] not in present:
                    nd = phi + rw[1]
                    if nd < best_d:
                        best_id, best_d = rw[0], nd
            if best_id != PAD_ID and (v not in repl or best_d < repl[v][1]):
                repl[v] = (best_id, best_d)
                changed = True
    for v, (rid, rd) in repl.items():
        nk = int((index.ids[v] != PAD_ID).sum())
        index.ids[v, nk] = rid
        index.dists[v, nk] = rd
    return len(affected)
