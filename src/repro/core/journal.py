"""Write-ahead update journal: durability for the staged-update queue.

The serving engine acknowledges a staged update (``stage_insert`` /
``stage_delete`` / ``stage_move``) the moment the call returns — from that
point the update MUST survive a process kill, even though it is not yet
applied to the tables and the artifact on disk still holds an older epoch.
``UpdateJournal`` is the standard WAL answer, sized to this system's tiny
record vocabulary:

* every acknowledged staged op is appended as one length+checksum framed
  record and fsync'd BEFORE the stage call returns;
* ``flush_updates`` appends a ``commit`` marker carrying the new epoch
  number after the table swap, so the journal records exactly which ops
  were batched into which flush (replay reproduces the same flush
  boundaries, which is what makes recovered tables byte-identical to an
  uncrashed engine's — the flush pipeline is deterministic per batch);
* ``replay()`` parses the record stream back into staged ops and commit
  markers. A torn tail — a partial frame from a kill mid-``write``, or
  garbage from a corrupted sector — fails its length/CRC check; the
  journal truncates the file back to the last whole record and reports
  what it dropped, instead of crashing or replaying garbage. Only records
  whose fsync never completed can be dropped this way, i.e. ops that were
  never acknowledged;
* the engine truncates the journal when the artifact is saved
  (``EngineCore.save``): at that point the artifact embodies every
  committed record, so the journal restarts empty. A flush commit alone
  does NOT truncate — the artifact on disk still predates the flush, and
  truncating there would lose the only durable copy of those updates.

Framing
-------
``8-byte magic | record*`` where each record is::

    u32 payload_len | u32 crc32(payload) | payload

and the payload is one tag byte plus little-endian int64 fields::

    b"I" u           stage_insert(u)
    b"D" u           stage_delete(u)
    b"M" u v         stage_move(u, v)
    b"C" epoch       flush committed -> epoch

``load``-time recovery (see ``EngineCore.load`` / ``attach_journal``):
replay every record through the engine's staged path, calling
``flush_updates`` at each commit marker; a trailing run of ops with no
marker (the crash interrupted or preceded their flush) is staged and
rolled forward as one final flush — the tables land exactly where the
crashed process was headed, because the index is a pure function of the
object set and the flush pipeline is deterministic per batch.
"""
from __future__ import annotations

import os
import struct
import zlib

from repro.core.errors import JournalError

_MAGIC = b"RKNNWAL1"
_FRAME = struct.Struct("<II")  # payload length, crc32(payload)
_I64 = struct.Struct("<q")
_I64x2 = struct.Struct("<qq")
# a record payload is 9 or 17 bytes today; anything bigger than this is
# garbage masquerading as a length field, not a future format extension
_MAX_PAYLOAD = 1 << 16

Record = tuple  # ("ins", u) | ("del", u) | ("mov", u, v) | ("commit", epoch)


class UpdateJournal:
    """Append-only fsync'd journal of staged ops + flush commit markers.

    ``fsync=False`` drops the per-record fsync (flush-to-OS only) for
    benchmarks that measure journaling overhead separately from disk sync
    latency; durability against process kill is kept (the OS holds the
    bytes), durability against power loss is not.
    """

    def __init__(self, path, *, fsync: bool = True):
        self.path = os.fspath(path)
        self.fsync = bool(fsync)
        self.dropped_bytes = 0  # torn/garbage tail bytes discarded by replay
        size = os.path.getsize(self.path) if os.path.exists(self.path) else 0
        # A file shorter than the magic can only be a kill between creation
        # and the magic fsync: zero records were ever acknowledged through
        # it, so recover it as a fresh journal instead of refusing to open.
        fresh = size < len(_MAGIC)
        self._f = open(self.path, "a+b")
        if fresh:
            self._f.truncate(0)
            self._f.write(_MAGIC)
            self._sync()
        else:
            self._f.seek(0)
            head = self._f.read(len(_MAGIC))
            if head != _MAGIC:
                self._f.close()
                raise JournalError(
                    f"{self.path} is not an update journal "
                    f"(bad magic {head!r}, expected {_MAGIC!r})"
                )

    # ------------------------------------------------------------------
    # writing
    # ------------------------------------------------------------------

    def _sync(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def _append(self, payload: bytes) -> None:
        self._f.write(_FRAME.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._sync()

    def append_op(self, op: Record) -> None:
        """Durably record one staged op BEFORE it is acknowledged."""
        kind = op[0]
        if kind == "ins":
            self._append(b"I" + _I64.pack(op[1]))
        elif kind == "del":
            self._append(b"D" + _I64.pack(op[1]))
        elif kind == "mov":
            self._append(b"M" + _I64x2.pack(op[1], op[2]))
        else:
            raise JournalError(f"unknown staged op kind {kind!r}")

    def commit(self, epoch: int) -> None:
        """Mark every op appended since the previous marker as flushed
        into ``epoch``. Written AFTER the in-memory table swap: a kill
        between swap and marker just re-runs that flush on replay."""
        self._append(b"C" + _I64.pack(int(epoch)))

    def truncate(self) -> None:
        """Reset to an empty journal (magic only). Correct only once the
        artifact on disk embodies every committed record — the engine
        calls this from ``save``, never from a flush."""
        self._f.truncate(len(_MAGIC))
        self._sync()

    # ------------------------------------------------------------------
    # reading / recovery
    # ------------------------------------------------------------------

    def replay(self) -> list[Record]:
        """Parse the journal back into ops + commit markers, in order.

        A torn or garbage tail (bad length, bad CRC, unknown tag, short
        frame) ends the parse at the last whole record: the file is
        truncated back to that point (so later appends never interleave
        with garbage) and the dropped byte count is recorded in
        ``self.dropped_bytes``. Corruption can only live in the tail —
        every earlier record was fsync'd before its op was acknowledged.
        ``dropped_bytes`` describes THIS replay only — it resets to 0 on
        entry so a clean replay never reports an earlier replay's tail.
        """
        self.dropped_bytes = 0
        self._f.seek(0)
        buf = self._f.read()
        out: list[Record] = []
        pos = len(_MAGIC)
        good = pos
        while pos < len(buf):
            if pos + _FRAME.size > len(buf):
                break  # torn frame header
            length, crc = _FRAME.unpack_from(buf, pos)
            start = pos + _FRAME.size
            if length > _MAX_PAYLOAD or start + length > len(buf):
                break  # garbage length / torn payload
            payload = buf[start : start + length]
            if zlib.crc32(payload) != crc:
                break  # bit rot or torn write inside the payload
            rec = self._decode(payload)
            if rec is None:
                break  # unknown tag: not ours, stop before it
            out.append(rec)
            pos = start + length
            good = pos
        if good < len(buf):
            self.dropped_bytes = len(buf) - good
            self._f.truncate(good)
            self._sync()
        return out

    @staticmethod
    def _decode(payload: bytes) -> Record | None:
        tag, body = payload[:1], payload[1:]
        try:
            if tag == b"I":
                return ("ins", _I64.unpack(body)[0])
            if tag == b"D":
                return ("del", _I64.unpack(body)[0])
            if tag == b"M":
                return ("mov", *_I64x2.unpack(body))
            if tag == b"C":
                return ("commit", _I64.unpack(body)[0])
        except struct.error:
            return None
        return None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "UpdateJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return f"UpdateJournal({self.path!r}, fsync={self.fsync})"
