"""TEN-Index-lite: the paper's state-of-the-art baseline (Ouyang et al.,
SIGMOD'20), reimplemented at benchmark scale.

Three parts, exactly as §3 describes:
  1. tree decomposition (min-degree elimination; bag X(v) = v + its
     higher-ranked clique neighbors; parent = lowest-ranked bag member)
  2. H2H-style distance labels: dist(v, a) for every ancestor a  — the O(n*h)
     part that dominates TEN-Index space (169 GB of 172 GB on USA)
  3. kTNN: top-k nearest objects inside each subtree, built bottom-up with
     H2H distance queries

Query: iterate p over anc(u) + u, refine kTNN(p) by dist(u,p), k rounds.
This mirrors TEN-Index's O(h*k) query and O(n*h) space against which the
paper's O(k) / O(n*k) are measured.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.bngraph import _mindegree_order
from repro.core.index import KNNIndex, index_from_lists
from repro.graph.csr import Graph


class TENIndexLite:
    def __init__(self, g: Graph, objects: np.ndarray, k: int):
        self.n = g.n
        self.k = k
        adj = g.adjacency_dicts()
        order = _mindegree_order(adj)  # mutates adj = step-1 elimination
        rank = np.empty(g.n, dtype=np.int64)
        rank[order] = np.arange(g.n)
        self.rank = rank
        self.order = order

        # --- bags, parents, depths ---
        self.bag: list[list[tuple[int, float]]] = [[] for _ in range(g.n)]
        parent = np.full(g.n, -1, dtype=np.int64)
        for v in range(g.n):
            hi = [(u, w) for u, w in adj[v].items() if rank[u] > rank[v]]
            hi.sort(key=lambda t: rank[t[0]])
            self.bag[v] = hi
            if hi:
                parent[v] = hi[0][0]
        self.parent = parent
        depth = np.zeros(g.n, dtype=np.int64)
        for r in range(g.n - 1, -1, -1):
            v = order[r]
            if parent[v] >= 0:
                depth[v] = depth[parent[v]] + 1
        self.depth = depth

        # --- H2H labels: dist to every ancestor, top-down ---
        self.label: list[dict[int, float]] = [dict() for _ in range(g.n)]
        for r in range(g.n - 1, -1, -1):
            v = order[r]
            anc = self._ancestors(v)
            lab = self.label[v]
            for a in anc:
                best = np.inf
                for u, w in self.bag[v]:
                    if u == a:
                        d = w
                    elif a in self.label[u]:
                        d = w + self.label[u][a]
                    elif u in self.label[a]:
                        d = w + self.label[a][u]
                    else:
                        continue
                    if d < best:
                        best = d
                lab[a] = best

        # --- kTNN: "constructed by querying the shortest distance of
        # corresponding vertex pairs through H2H-Index" (paper §3). Every
        # object o lies in T(a) for each ancestor a, so o pushes its H2H
        # distance into the capped top-k heap of its whole ancestor chain.
        heaps: list[list[tuple[float, int]]] = [[] for _ in range(g.n)]

        def push(v: int, o: int, d: float) -> None:
            h = heaps[v]
            item = (-d, o)
            if len(h) < k:
                heapq.heappush(h, item)
            elif item > h[0]:
                heapq.heapreplace(h, item)

        for o in objects.tolist():
            push(o, o, 0.0)
            for a in self._ancestors(int(o)):
                push(a, o, self.dist(a, int(o)))
        self.ktnn: list[list[tuple[int, float]]] = [
            [(o, -nd) for nd, o in sorted(h, reverse=True)] for h in heaps
        ]

    def _ancestors(self, v: int) -> list[int]:
        out = []
        p = self.parent[v]
        while p >= 0:
            out.append(int(p))
            p = self.parent[p]
        return out

    # -- H2H-style point-to-point distance query --
    def dist(self, u: int, v: int) -> float:
        if u == v:
            return 0.0
        du, dv = self.label[u], self.label[v]
        if v in du:
            return du[v]
        if u in dv:
            return dv[u]
        # LCA by walking up
        a, b = u, v
        while a != b:
            if self.depth[a] >= self.depth[b]:
                a = int(self.parent[a])
            else:
                b = int(self.parent[b])
        x = a
        cands = [x] + [w for w, _ in self.bag[x]]
        best = np.inf
        for w in cands:
            d1 = 0.0 if w == u else du.get(w, np.inf)
            d2 = 0.0 if w == v else dv.get(w, np.inf)
            if d1 + d2 < best:
                best = d1 + d2
        return best

    # -- kNN query (paper §3: iterate anc(u)+u, refine kTNN) --
    def knn(self, u: int, k: int | None = None) -> list[tuple[int, float]]:
        kk = self.k if k is None else min(k, self.k)
        cands: dict[int, float] = {}
        for p in [u] + self._ancestors(u):
            dup = 0.0 if p == u else self.dist(u, p)
            for o, dpo in self.ktnn[p]:
                d = dup + dpo
                old = cands.get(o)
                if old is None or d < old:
                    cands[o] = d
        return [(o, d) for d, o in heapq.nsmallest(kk, ((d, o) for o, d in cands.items()))]

    def size_entries(self) -> dict[str, int]:
        h2h = sum(len(l) for l in self.label)
        ktnn = sum(len(t) for t in self.ktnn)
        bags = sum(len(b) for b in self.bag)
        return {"h2h_entries": h2h, "ktnn_entries": ktnn, "bag_entries": bags}

    def size_bytes(self) -> int:
        s = self.size_entries()
        return 8 * (s["h2h_entries"] + s["ktnn_entries"] + s["bag_entries"])

    def build_knn_index(self) -> KNNIndex:
        """TEN-Index-Cons baseline: materialise KNN-Index via TEN queries."""
        rows = [self.knn(u) for u in range(self.n)]
        return index_from_lists(self.n, self.k, rows)
