"""Typed error taxonomy for the kNN serving system.

Every failure mode the engine can surface to a caller is a subclass of
``RepError``, so ``except RepError`` catches exactly "this system rejected
the request / detected corruption" without also swallowing genuine bugs
(``TypeError``, ``AttributeError``, ...). Each subclass ALSO inherits the
builtin exception the pre-taxonomy code raised for that condition
(``ValueError`` for request validation, ``RuntimeError`` for state/
durability violations), so existing ``except ValueError`` call sites — and
the seed test suite's ``pytest.raises`` assertions — keep working unchanged.

The taxonomy, by layer:

* ``QueryError`` — a malformed query request: ``k`` exceeding the index's
  k, a per-query k vector of the wrong shape, a non-1-D query batch.
* ``StagedUpdateError`` — a staged update the engine must refuse:
  insert of a present object, delete of an absent one, a self-move, a
  vertex outside ``[0, n)``.
* ``EngineConfigError`` — an invalid engine configuration value, e.g. an
  unknown ``engine.frontier`` pipeline name.
* ``EpochError`` — an epoch request the retention policy cannot serve
  (already-evicted or never-published epoch, ``keep_epochs < 1``).
* ``ArtifactError`` — a persistence-layer violation: saving with staged
  updates pending, loading a truncated/corrupted npz, a content-checksum
  mismatch, a schema version newer than this code understands.
* ``JournalError`` — a write-ahead journal file that cannot be used at
  all (bad magic/header). Torn or garbage record *tails* are NOT errors:
  the journal truncates them cleanly on replay (crash recovery), so only
  a file that was never a journal raises.
* ``SanitizerError`` — a device-residency invariant violated at runtime,
  caught by the sanitizer rail (``repro.analysis.sanitize``): a host
  transfer on a guarded query/flush path, a compile-budget overrun, a
  NaN/negative-distance/corrupt-id table entry after a flush, or a Pallas
  kernel diverging from its host oracle under poisoned buffers.

Exported through the ``repro.knn`` facade.
"""
from __future__ import annotations


class RepError(Exception):
    """Base class for every typed error this system raises."""


class QueryError(RepError, ValueError):
    """A query request the engine cannot serve (bad k / batch shape)."""


class StagedUpdateError(RepError, ValueError):
    """A staged object update that violates the object-set state."""


class EngineConfigError(RepError, ValueError):
    """An invalid engine configuration value (e.g. unknown pipeline name)."""


class EpochError(RepError, ValueError):
    """An epoch that is unknown, already evicted, or an invalid retention."""


class ArtifactError(RepError, RuntimeError):
    """A persistence violation: corrupt/stale artifact or unsafe save."""


class JournalError(ArtifactError):
    """A file that is not a usable write-ahead journal (bad magic/header)."""


class SanitizerError(RepError, RuntimeError):
    """A device-residency invariant violated at runtime (sanitizer rail)."""
