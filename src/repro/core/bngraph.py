"""BN-Graph construction — Algorithm 1 (SD-Graph-Gen) of the paper.

Builds the bridge-neighbor-preserved graph G' of a road network G:
  (1) V(G') = V(G)
  (2) every edge weight in G' equals the true shortest distance in G
  (3) all pairwise shortest distances are preserved.

Step 1 (edge insertion) is the classic contraction-style elimination: process
vertices in increasing rank order, and form a clique (with min-plus weights)
among the still-unprocessed (= higher-ranked) neighbors of each processed
vertex. Step 2 (edge deletion) walks ranks downward and replaces every edge
weight by the exact distance, deleting edges that are not bridges.

The vertex order is the paper's dynamic minimum-degree heuristic by default
(Section 5.2 Remark): the next vertex is the one with the fewest *unprocessed*
neighbors in the current G'. 'degree' (static) and 'id' orders are provided
for the Exp-10 reproduction.

This pass mutates graph structure dynamically and is therefore kept on the
host (numpy/python), exactly as sparse direct solvers keep symbolic
factorisation on CPU; the numeric sweeps that dominate construction time run
on TPU (see construct_jax.py).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.graph.csr import Graph, PaddedCSR, padded_csr


@dataclasses.dataclass
class BNGraph:
    """The bridge-neighbor preserved graph G' plus the schedule metadata."""

    n: int
    rank: np.ndarray            # (n,) int64: rank[v] = position of v in pi
    order: np.ndarray           # (n,) int64: order[r] = vertex with rank r
    # Final G' adjacency split by rank direction, padded with -1 / +inf:
    lo_ids: np.ndarray          # (n, tau_lo) int32   BNS^<(v)
    lo_w: np.ndarray            # (n, tau_lo) float64 exact distances
    hi_ids: np.ndarray          # (n, tau_hi) int32   BNS^>(v)
    hi_w: np.ndarray            # (n, tau_hi) float64 exact distances
    # Level schedule (ours): levels_up for the bottom-up sweep over BNS^<,
    # levels_down for the top-down sweep over BNS^>.
    level_up: np.ndarray        # (n,) int32
    level_down: np.ndarray      # (n,) int32
    rho: int                    # max degree after step 1 (paper's rho)

    @property
    def tau(self) -> int:
        """max |BNS^>(v)| (paper's tau)."""
        return int((self.hi_ids >= 0).sum(axis=1).max())

    @property
    def tau_all(self) -> int:
        """max |BNS(v)| (paper's tau')."""
        return int(((self.hi_ids >= 0).sum(axis=1) + (self.lo_ids >= 0).sum(axis=1)).max())

    def sweep_tables(self, direction: str) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(level_of, neighbor ids, neighbor weights) for one sweep direction.

        "up" is the bottom-up sweep over BNS^< (increasing rank), "down" the
        top-down sweep over BNS^> (decreasing rank). This is the schedule
        layout consumed by construct_jax.prepare_sweep.
        """
        if direction == "up":
            return self.level_up, self.lo_ids, self.lo_w
        if direction == "down":
            return self.level_down, self.hi_ids, self.hi_w
        raise ValueError(f"direction must be 'up' or 'down', got {direction!r}")

    def level_members(self, direction: str) -> list[np.ndarray]:
        """Vertices of each DAG level, in level order (device sweep batches).

        Vertices within one level are mutually independent: every bridge
        neighbor a level-l vertex reads lives in a strictly earlier level.
        """
        level_of, _, _ = self.sweep_tables(direction)
        nlev = int(level_of.max()) + 1 if self.n else 0
        order = np.argsort(level_of, kind="stable")
        bounds = np.searchsorted(level_of[order], np.arange(nlev + 1))
        return [
            order[bounds[lv] : bounds[lv + 1]].astype(np.int32)
            for lv in range(nlev)
            if bounds[lv + 1] > bounds[lv]
        ]

    def bns_lower(self, v: int) -> list[tuple[int, float]]:
        ids = self.lo_ids[v]
        sel = ids >= 0
        return list(zip(ids[sel].tolist(), self.lo_w[v][sel].tolist()))

    def bns_higher(self, v: int) -> list[tuple[int, float]]:
        ids = self.hi_ids[v]
        sel = ids >= 0
        return list(zip(ids[sel].tolist(), self.hi_w[v][sel].tolist()))

    def bns(self, v: int) -> list[tuple[int, float]]:
        return self.bns_lower(v) + self.bns_higher(v)

    def bns_packed(self) -> PaddedCSR:
        """Combined BNS^< + BNS^> adjacency as one ``PaddedCSR`` (cached).

        The padded ``(n+1, t)`` tables (valid-first compacted, dummy row
        last, float32 weights) are the layout every batched device pass over
        BNS neighborhoods gathers from — the engine repair rounds and the
        batched checkIns frontier upload per-width-bucket column slices of
        them once and reuse them across flushes, replacing the per-vertex
        host ``bns()`` walk. The CSR triple serves host-side set algebra
        (e.g. expanding a changed-vertex frontier to its receiver set).
        Built on first use and memoized on the instance; treat the BNGraph
        as immutable once handed to an engine.
        """
        packed = getattr(self, "_bns_packed", None)
        if packed is None:
            packed = padded_csr(
                np.concatenate([self.lo_ids, self.hi_ids], axis=1),
                np.concatenate([self.lo_w, self.hi_w], axis=1),
            )
            self._bns_packed = packed
        return packed

    def adjacency(self) -> list[dict[int, float]]:
        adj: list[dict[int, float]] = [dict() for _ in range(self.n)]
        for v in range(self.n):
            for u, w in self.bns(v):
                adj[v][u] = w
        return adj


def _mindegree_order(adj: list[dict[int, float]]) -> np.ndarray:
    """Interleaved edge-insertion + dynamic min-degree rank (paper's order).

    Mutates adj in place (this IS step 1 of Algorithm 1); returns order.
    Ties broken by smallest vertex id, per the paper.
    """
    n = len(adj)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    heap: list[tuple[int, int]] = [(int(deg[v]), v) for v in range(n)]
    heapq.heapify(heap)
    processed = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    r = 0
    while heap:
        d, w = heapq.heappop(heap)
        if processed[w] or d != deg[w]:
            continue  # stale heap entry
        processed[w] = True
        order[r] = w
        r += 1
        nbrs = [v for v in adj[w] if not processed[v]]
        # Contract w: clique among unprocessed neighbors.
        for i, u in enumerate(nbrs):
            au = adj[u]
            w_uw = adj[w][u]
            for v in nbrs[i + 1 :]:
                cand = w_uw + adj[w][v]
                old = au.get(v)
                if old is None:
                    au[v] = cand
                    adj[v][u] = cand
                    deg[u] += 1
                    deg[v] += 1
                    heapq.heappush(heap, (int(deg[v]), v))
                elif cand < old:
                    au[v] = cand
                    adj[v][u] = cand
            # processing w removes it from u's unprocessed neighborhood
            deg[u] -= 1
            heapq.heappush(heap, (int(deg[u]), u))
    return order


def _static_order_insertion(adj: list[dict[int, float]], order: np.ndarray) -> None:
    """Step 1 of Algorithm 1 under a fixed total order (Exp-10 variants)."""
    n = len(adj)
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    for w in order.tolist():
        rw = rank[w]
        nbrs = [v for v in adj[w] if rank[v] > rw]
        for i, u in enumerate(nbrs):
            au = adj[u]
            w_uw = adj[w][u]
            for v in nbrs[i + 1 :]:
                cand = w_uw + adj[w][v]
                old = au.get(v)
                if old is None or cand < old:
                    au[v] = cand
                    adj[v][u] = cand


def build_bngraph(g: Graph, *, order: str | np.ndarray = "mindeg") -> BNGraph:
    """Algorithm 1: SD-Graph-Gen(G, pi) + level schedule extraction."""
    adj = g.adjacency_dicts()

    # ---- Step 1: edge insertion (+ order computation when dynamic) ----
    if isinstance(order, str) and order == "mindeg":
        order_arr = _mindegree_order(adj)
    else:
        if isinstance(order, str):
            if order == "id":
                order_arr = np.arange(g.n, dtype=np.int64)
            elif order == "degree":
                deg = g.degrees()
                order_arr = np.lexsort((np.arange(g.n), deg)).astype(np.int64)
            else:
                raise ValueError(f"unknown order {order!r}")
        else:
            order_arr = np.asarray(order, dtype=np.int64)
        _static_order_insertion(adj, order_arr)

    n = g.n
    rank = np.empty(n, dtype=np.int64)
    rank[order_arr] = np.arange(n)
    rho = max(len(a) for a in adj) if n else 0

    # ---- Step 2: edge deletion (exact-distance relaxation, decreasing rank) ----
    removed: set[tuple[int, int]] = set()
    for r in range(n - 1, -1, -1):
        w = int(order_arr[r])
        aw = adj[w]
        nbrs = [v for v in aw if rank[v] > r]
        if len(nbrs) < 2:
            continue
        snap = {v: aw[v] for v in nbrs}  # snapshot of phi(w, .) before updates
        for u in nbrs:
            best = snap[u]
            improved = False
            for v in nbrs:
                if v == u:
                    continue
                wu = adj[v].get(u)
                if wu is None:
                    continue  # (v,u) was already deleted in step 2
                cand = snap[v] + wu
                if cand < best:
                    best = cand
                    improved = True
            if improved:
                aw[u] = best
                adj[u][w] = best
                removed.add((w, u))
    for w, u in removed:
        adj[w].pop(u, None)
        adj[u].pop(w, None)

    # ---- Split adjacency by rank, pad, and derive the level schedule ----
    lo_lists: list[list[tuple[int, float]]] = [[] for _ in range(n)]
    hi_lists: list[list[tuple[int, float]]] = [[] for _ in range(n)]
    for v in range(n):
        rv = rank[v]
        for u, wgt in adj[v].items():
            (lo_lists[v] if rank[u] < rv else hi_lists[v]).append((int(u), float(wgt)))
    for v in range(n):
        lo_lists[v].sort(key=lambda t: t[1])
        hi_lists[v].sort(key=lambda t: t[1])

    tau_lo = max((len(l) for l in lo_lists), default=0)
    tau_hi = max((len(l) for l in hi_lists), default=0)
    lo_ids = np.full((n, max(tau_lo, 1)), -1, dtype=np.int32)
    lo_w = np.full((n, max(tau_lo, 1)), np.inf, dtype=np.float64)
    hi_ids = np.full((n, max(tau_hi, 1)), -1, dtype=np.int32)
    hi_w = np.full((n, max(tau_hi, 1)), np.inf, dtype=np.float64)
    for v in range(n):
        for j, (u, wgt) in enumerate(lo_lists[v]):
            lo_ids[v, j], lo_w[v, j] = u, wgt
        for j, (u, wgt) in enumerate(hi_lists[v]):
            hi_ids[v, j], hi_w[v, j] = u, wgt

    # Level schedule: level_up via BNS^< in increasing rank order; level_down
    # via BNS^> in decreasing rank order. Vertices within a level are
    # independent, which is what lets the TPU sweeps batch them.
    level_up = np.zeros(n, dtype=np.int32)
    for r in range(n):
        v = int(order_arr[r])
        ids = lo_ids[v][lo_ids[v] >= 0]
        if ids.size:
            level_up[v] = int(level_up[ids].max()) + 1
    level_down = np.zeros(n, dtype=np.int32)
    for r in range(n - 1, -1, -1):
        v = int(order_arr[r])
        ids = hi_ids[v][hi_ids[v] >= 0]
        if ids.size:
            level_down[v] = int(level_down[ids].max()) + 1

    return BNGraph(
        n=n,
        rank=rank,
        order=order_arr,
        lo_ids=lo_ids,
        lo_w=lo_w,
        hi_ids=hi_ids,
        hi_w=hi_w,
        level_up=level_up,
        level_down=level_down,
        rho=rho,
    )
