"""KNN-Index structure (Definition 4.1) and query processing (§4.1).

The index is exactly what the paper stores: for every vertex v, the top-k
nearest candidate objects in increasing distance order. Query = O(k) scan
(Theorem 4.3, optimal); progressive output of the i-th result in O(i)
(Theorem 4.4); size O(n*k) (Theorem 4.5).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

PAD_ID = -1
PAD_DIST = np.inf


@dataclasses.dataclass
class KNNIndex:
    """ids[v, i] = i-th nearest object of v; dists[v, i] = its distance."""

    ids: np.ndarray    # (n, k) int32, PAD_ID padded
    dists: np.ndarray  # (n, k) float64, PAD_DIST padded
    k: int

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    def query(self, u: int, k: int | None = None) -> list[tuple[int, float]]:
        """Answer a kNN query by scanning the u-th row — O(k), Theorem 4.3."""
        kk = self.k if k is None else min(k, self.k)
        row_ids = self.ids[u, :kk]
        row_d = self.dists[u, :kk]
        sel = row_ids != PAD_ID
        return list(zip(row_ids[sel].tolist(), row_d[sel].tolist()))

    def query_progressive(self, u: int, k: int | None = None) -> Iterator[tuple[int, float]]:
        """Progressive query processing: yields the i-th result in O(1) more
        work after the (i-1)-th (Theorem 4.4, incremental polynomial)."""
        kk = self.k if k is None else min(k, self.k)
        for i in range(kk):
            v = int(self.ids[u, i])
            if v == PAD_ID:
                return
            yield v, float(self.dists[u, i])

    def size_bytes(self, id_bytes: int = 4, dist_bytes: int = 4) -> int:
        """Index size as the paper counts it (Exp-5/6): n*k (id+dist) entries."""
        return self.n * self.k * (id_bytes + dist_bytes)

    def copy(self) -> "KNNIndex":
        return KNNIndex(ids=self.ids.copy(), dists=self.dists.copy(), k=self.k)


def index_from_lists(n: int, k: int, rows: list[list[tuple[int, float]]]) -> KNNIndex:
    ids = np.full((n, k), PAD_ID, dtype=np.int32)
    dists = np.full((n, k), PAD_DIST, dtype=np.float64)
    for v, row in enumerate(rows):
        for i, (obj, d) in enumerate(row[:k]):
            ids[v, i] = obj
            dists[v, i] = d
    return KNNIndex(ids=ids, dists=dists, k=k)


def indices_equivalent(a: KNNIndex, b: KNNIndex, *, atol: float = 1e-9) -> bool:
    """Equality up to ties: the distance rows must match exactly; ids may
    differ only where distances tie."""
    if a.n != b.n or a.k != b.k:
        return False
    if not np.allclose(
        np.where(np.isinf(a.dists), -1.0, a.dists),
        np.where(np.isinf(b.dists), -1.0, b.dists),
        atol=atol,
    ):
        return False
    return True
