"""KNN-Index structure (Definition 4.1) and query processing (§4.1).

The index is exactly what the paper stores: for every vertex v, the top-k
nearest candidate objects in increasing distance order. Query = O(k) scan
(Theorem 4.3, optimal); progressive output of the i-th result in O(i)
(Theorem 4.4); size O(n*k) (Theorem 4.5).

``KNNIndex`` is the *host* view: plain numpy tables plus scalar per-call
queries, kept as the readable reference the oracles (core/reference.py,
core/updates.py) operate on. Production serving goes through the
device-resident ``repro.core.engine.QueryEngine`` (batched queries, staged
updates, save/load), re-exported with this class from the stable
``repro.knn`` facade.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

PAD_ID = -1
PAD_DIST = np.inf


@dataclasses.dataclass
class KNNIndex:
    """ids[v, i] = i-th nearest object of v; dists[v, i] = its distance."""

    ids: np.ndarray    # (n, k) int32, PAD_ID padded
    dists: np.ndarray  # (n, k) float64, PAD_DIST padded
    k: int

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    def _check_k(self, k: int | None) -> int:
        if k is None:
            return self.k
        if k > self.k:
            raise ValueError(
                f"query k={k} exceeds index k={self.k}: a k'-NN query is only "
                f"answerable from a KNN-Index built with k >= k' (Section 4.2)"
            )
        return k

    def query(self, u: int, k: int | None = None) -> list[tuple[int, float]]:
        """Answer a kNN query by scanning the u-th row — O(k), Theorem 4.3.

        Raises ValueError when k exceeds the index's k: the row only stores
        the k nearest objects, so a larger query cannot be answered.
        """
        kk = self._check_k(k)
        row_ids = self.ids[u, :kk]
        row_d = self.dists[u, :kk]
        sel = row_ids != PAD_ID
        return list(zip(row_ids[sel].tolist(), row_d[sel].tolist()))

    def query_progressive(self, u: int, k: int | None = None) -> Iterator[tuple[int, float]]:
        """Progressive query processing: yields the i-th result in O(1) more
        work after the (i-1)-th (Theorem 4.4, incremental polynomial)."""
        kk = self._check_k(k)
        for i in range(kk):
            v = int(self.ids[u, i])
            if v == PAD_ID:
                return
            yield v, float(self.dists[u, i])

    def size_bytes(self, id_bytes: int = 4, dist_bytes: int = 8) -> int:
        """Size in bytes of the stored tables: n*k (id + dist) entries.

        The paper's O(n*k) size bound (Theorem 4.5, Exp-5/6) counts 4-byte
        ids and 4-byte float distances — n*k*8 bytes, what the device tables
        (int32/float32) occupy; call ``size_bytes(dist_bytes=4)`` for that
        figure. The defaults describe *this* host object, whose ``dists``
        are float64 so the update oracles accumulate in full precision.
        """
        return self.n * self.k * (id_bytes + dist_bytes)

    def copy(self) -> "KNNIndex":
        return KNNIndex(ids=self.ids.copy(), dists=self.dists.copy(), k=self.k)


def index_from_lists(n: int, k: int, rows: list[list[tuple[int, float]]]) -> KNNIndex:
    ids = np.full((n, k), PAD_ID, dtype=np.int32)
    dists = np.full((n, k), PAD_DIST, dtype=np.float64)
    for v, row in enumerate(rows):
        for i, (obj, d) in enumerate(row[:k]):
            ids[v, i] = obj
            dists[v, i] = d
    return KNNIndex(ids=ids, dists=dists, k=k)


def indices_equivalent(a: KNNIndex, b: KNNIndex, *, atol: float = 1e-9) -> bool:
    """Equality up to ties: the distance rows must match exactly; ids may
    differ only where distances tie.

    Rows are sorted by distance, so an entry's distance is ambiguous (a tie)
    exactly when it equals an adjacent entry's distance; everywhere else the
    object id is uniquely determined and must match — except in the last slot
    of a *full* row, where a tie can hide below the cut: the k-th and the
    discarded (k+1)-th candidate may sit at the same distance, and the update
    algorithms (checkIns prunes at d < kth) legitimately keep either one.
    """
    if a.n != b.n or a.k != b.k:
        return False
    da = np.where(np.isinf(a.dists), -1.0, a.dists)
    db = np.where(np.isinf(b.dists), -1.0, b.dists)
    if not np.allclose(da, db, atol=atol):
        return False
    tie = np.zeros(da.shape, dtype=bool)
    if a.k > 1:
        adj = np.isclose(da[:, 1:], da[:, :-1], atol=atol)
        tie[:, 1:] |= adj
        tie[:, :-1] |= adj
    tie[:, -1] |= a.ids[:, -1] != PAD_ID  # full row: boundary tie is invisible
    unique = ~tie & np.isfinite(a.dists)
    return bool(np.array_equal(a.ids[unique], b.ids[unique]))
