# The paper's primary contribution — the SYSTEM lives here:
#   bngraph.py       Algorithm 1 (BN-Graph, host symbolic phase)
#   reference.py     Algorithms 2/3 host oracles
#   construct_jax.py device-resident fused construction sweeps
#   index.py         host KNNIndex view (Definition 4.1, O(k) query)
#   updates.py       Algorithms 4/5 scalar host oracle
#   engine.py        device-resident batched QueryEngine (serving surface)
# Public entry point: the stable `repro.knn` facade.
