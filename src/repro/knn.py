"""Stable public facade for the kNN road-network system.

One import surface for the whole pipeline — build, serve, maintain, persist:

    from repro import knn

    g = knn.road_network(64, 64, seed=0)
    objects = knn.pick_objects(g.n, 0.02, seed=0)
    engine = knn.build_engine(g, objects, k=20)        # device sweeps end to end

    ids, dists = engine.query_batch(us)                # batched O(k) serving
    engine.stage_insert(u); engine.stage_delete(v)
    engine.stage_move(a, b)                            # moving-objects traffic
    engine.flush_updates()                             # one fused batch repair
    engine.save("index.npz")

    engine = knn.load_engine("index.npz", bn=knn.build_bngraph(g))

Moving-fleet serving (see ``repro.workloads``): ``FleetSim`` drives vehicles
along shortest-path trips and each ``sim.tick()`` yields the (src, dst) moves
to stage; ``flush_updates`` applies them as one fused device batch.

Multi-device serving: ``build_sharded_engine`` (and ``load_engine(...,
shards=N)``) returns a ``ShardedQueryEngine`` — the same surface served from
vertex-sharded tables on a 1-D device mesh, exactly equivalent to the scalar
engine (tests/core/test_sharded.py). Everything re-exported here is covered
by the equivalence tests, so internal layouts may change under it without
breaking callers.

Durability and failure taxonomy: ``load_engine(..., journal="wal.bin")``
attaches a write-ahead ``UpdateJournal`` and replays any records a killed
process left behind (crash recovery to byte-identical tables — see
``repro.core.journal``). Every error the system raises subclasses
``RepError`` (``repro.core.errors``): catch it to handle exactly
"this system rejected the request / detected corruption".
"""
from __future__ import annotations

import numpy as np

from repro.core.bngraph import BNGraph, build_bngraph
from repro.core.construct_jax import build_knn_index_jax, build_knn_tables_jax
from repro.core.engine import QueryEngine
from repro.core.errors import (
    ArtifactError,
    EngineConfigError,
    EpochError,
    JournalError,
    QueryError,
    RepError,
    StagedUpdateError,
)
from repro.core.index import KNNIndex, indices_equivalent
from repro.core.journal import UpdateJournal
from repro.core.partition import PartitionPlan, propose_starts
from repro.core.reference import knn_index_cons_plus
from repro.core.sharded import ShardedQueryEngine, ShardRoutingTable, make_mesh
from repro.core.updates import delete_object, insert_object, move_object
from repro.graph.csr import Graph
from repro.graph.generators import pick_objects, road_network
from repro.workloads.fleet import FleetSim

__all__ = [
    "ArtifactError",
    "BNGraph",
    "EngineConfigError",
    "EpochError",
    "FleetSim",
    "Graph",
    "JournalError",
    "KNNIndex",
    "PartitionPlan",
    "QueryEngine",
    "QueryError",
    "RepError",
    "ShardRoutingTable",
    "ShardedQueryEngine",
    "StagedUpdateError",
    "UpdateJournal",
    "build_bngraph",
    "build_engine",
    "build_index",
    "build_knn_index_jax",
    "build_knn_tables_jax",
    "build_sharded_engine",
    "delete_object",
    "indices_equivalent",
    "insert_object",
    "knn_index_cons_plus",
    "load_engine",
    "make_mesh",
    "move_object",
    "pick_objects",
    "propose_starts",
    "road_network",
    "stage_random_updates",
]


def build_engine(
    graph: Graph | BNGraph,
    objects: np.ndarray,
    k: int,
    *,
    use_pallas: bool = False,
) -> QueryEngine:
    """Road network (or prebuilt BN-Graph) -> serving engine, on device."""
    bn = graph if isinstance(graph, BNGraph) else build_bngraph(graph)
    return QueryEngine.build(bn, objects, k, use_pallas=use_pallas)


def build_index(
    graph: Graph | BNGraph,
    objects: np.ndarray,
    k: int,
    *,
    use_pallas: bool = False,
) -> KNNIndex:
    """Road network (or prebuilt BN-Graph) -> host KNNIndex view."""
    bn = graph if isinstance(graph, BNGraph) else build_bngraph(graph)
    return build_knn_index_jax(bn, objects, k, use_pallas=use_pallas)


def build_sharded_engine(
    graph: Graph | BNGraph,
    objects: np.ndarray,
    k: int,
    *,
    plan: PartitionPlan | str | None = None,
    shards: int | None = None,
    use_pallas: bool = False,
    replication: dict[int, int] | None = None,
) -> ShardedQueryEngine:
    """Road network -> vertex-sharded multi-device serving engine.

    ``plan`` — a ``PartitionPlan`` (or its ``parse`` spec string, e.g.
    ``"shards=4,ranges=auto"``) — is the one place the whole partition
    layout is specified: shard count, range boundaries (equal-width,
    explicit, or object-density ``auto``), replication and routing policy.
    The sharded engine serves the exact same results as the scalar one
    under every layout; see ``repro.core.sharded``.

    ``shards=`` and ``replication=`` are the legacy pre-plan kwargs, kept
    as thin deprecation shims that construct the equivalent plan (passing
    them alongside ``plan`` raises ``EngineConfigError``). ``shards=None``
    with no plan spans every visible device (on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before process
    start).
    """
    plan = PartitionPlan.resolve(plan, shards=shards, replication=replication)
    bn = graph if isinstance(graph, BNGraph) else build_bngraph(graph)
    return ShardedQueryEngine.build(bn, objects, k, plan=plan, use_pallas=use_pallas)


def load_engine(
    path,
    *,
    bn: BNGraph | None = None,
    plan: PartitionPlan | str | None = None,
    shards: int | None = None,
    use_pallas: bool = False,
    journal=None,
    replication: dict[int, int] | None = None,
) -> QueryEngine | ShardedQueryEngine:
    """Load a ``QueryEngine.save`` / ``knn_build --out`` artifact.

    ``plan`` (a ``PartitionPlan`` or spec string) naming a shard count
    loads into a ``ShardedQueryEngine`` under that layout regardless of how
    many shards wrote the artifact (reshard-on-load: the artifact stores
    the logical vertex-order tables, plus any uneven range boundaries the
    writer was serving under, which are reused when the shard count
    matches). No plan and ``shards=None`` keeps the scalar engine.

    ``shards=`` / ``replication=`` are the legacy deprecation-shim kwargs
    (mixing them with ``plan`` raises ``EngineConfigError``). A replication
    plan saved in the artifact is re-applied when compatible (same shard
    count, enough devices) and dropped otherwise; an explicit plan or
    ``replication={...}`` overrides it, ``{}`` force-drops it.

    ``journal`` (a path or ``UpdateJournal``) attaches the write-ahead
    journal and replays whatever a killed process left in it — committed
    flush segments and the uncommitted tail — recovering the exact tables
    that process was serving. Requires ``bn`` when the journal is
    non-empty (replay runs real updates).
    """
    plan = PartitionPlan.resolve(plan, shards=shards, replication=replication)
    if plan.shards is not None or plan.ranges is not None or plan.replication is not None:
        return ShardedQueryEngine.load(
            path, bn=bn, plan=plan, use_pallas=use_pallas, journal=journal,
        )
    return QueryEngine.load(path, bn=bn, use_pallas=use_pallas, journal=journal)


def stage_random_updates(engine: QueryEngine, mset: set, rng=None, count: int = 1) -> int:
    """Stage ``count`` random net object updates (the benchmark workload mix).

    Draws uniform vertices from the engine's *global* vertex set
    ``[0, engine.n)`` (a sharded engine is driven identically — routing by
    owner happens at flush time): a present one is staged for deletion
    (skipped while |M| <= k+1 so rows stay full through the churn), an
    absent one for insertion. ``mset`` is the caller's membership mirror and
    is kept in sync.

    ``rng`` may be a ``numpy.random.Generator``, an int seed, or None — the
    default is a fresh ``np.random.default_rng(0)``, so repeated runs that
    rely on the default draw the SAME update sequence (reproducible
    benchmarks; pass ``serve.py --seed`` / your own generator to vary it).
    Returns the number staged — possibly fewer than ``count`` when the draw
    budget runs out (e.g. every vertex is an object but |M| <= k+1, so
    nothing is stageable); the caller decides when to flush.
    """
    if rng is None or isinstance(rng, (int, np.integer)):
        rng = np.random.default_rng(0 if rng is None else int(rng))
    staged = 0
    for _ in range(max(16, 16 * count)):
        if staged >= count:
            break
        v = int(rng.integers(0, engine.n))
        if v in mset and len(mset) > engine.k + 1:
            engine.stage_delete(v)
            mset.discard(v)
        elif v not in mset:
            engine.stage_insert(v)
            mset.add(v)
        else:
            continue
        staged += 1
    return staged
