"""Serving workload generators for the kNN road-network system.

Each module here produces *traffic* — query mixes and object-update streams —
for the ``repro.knn`` serving surface; the engine itself stays workload-
agnostic. The flagship workload is the moving fleet (``fleet.FleetSim``):
vehicles drive shortest-path trips over the road network and every tick
yields a batch of ``(src, dst)`` moves to stage into the engine, the
location-based-service pattern (ride-hailing, delivery, tracking) where
update traffic is dominated by *movement* rather than appearance/churn.

Build -> simulate -> query while moving::

    from repro import knn

    g = knn.road_network(40, 40, seed=0)
    sim = knn.FleetSim(g, fleet_size=96, seed=0)
    engine = knn.build_engine(g, sim.positions, k=20)

    for _ in range(100):                      # one serving tick each
        for u, v in sim.tick():               # vehicles advance one street
            engine.stage_move(u, v)           # staged, not yet visible
        ids, dists = engine.query_batch(qs)   # queries see the flushed state
        engine.flush_updates()                # one fused move batch

``repro.launch.serve --arch knn-index --workload fleet`` runs this loop as a
service and ``benchmarks.paper_experiments.exp12_moving_fleet`` measures it.
"""
from repro.workloads.fleet import FleetSim, drive_fleet_ticks

__all__ = ["FleetSim", "drive_fleet_ticks"]
