"""Moving-fleet simulator: vehicles driving shortest-path trips on the network.

Each vehicle occupies one vertex (the engine's candidate objects ARE vertices,
so two vehicles never share one — a blocked vehicle waits, which is also what
real congestion looks like). A vehicle drives the shortest path to a randomly
drawn destination, one street per tick by default, and draws a fresh trip on
arrival. ``tick()`` returns the batch of ``(src, dst)`` moves executed that
tick, in an order that is always valid to stage sequentially into
``QueryEngine.stage_move`` (a vertex freed earlier in the tick may be entered
later in the same tick, never the reverse).

The simulator is deliberately host-side and deterministic (seeded): serving
benchmarks replay the *same* movement trace through different engine update
strategies (fused moves vs split delete+insert flushes) so throughput
differences measure the engine, not the traffic.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graph.csr import Graph


def shortest_path(g: Graph, src: int, dst: int) -> list[int]:
    """Dijkstra path src -> dst as a vertex list (inclusive of both ends)."""
    if src == dst:
        return [src]
    dist = np.full(g.n, np.inf)
    dist[src] = 0.0
    parent = np.full(g.n, -1, np.int64)
    heap: list[tuple[float, int]] = [(0.0, src)]
    while heap:
        d, v = heapq.heappop(heap)
        if v == dst:
            break
        if d > dist[v]:
            continue
        nbrs, ws = g.neighbors(v)
        for nb, w in zip(nbrs.tolist(), ws.tolist()):
            nd = d + w
            if nd < dist[nb]:
                dist[nb] = nd
                parent[nb] = v
                heapq.heappush(heap, (nd, nb))
    if not np.isfinite(dist[dst]):
        raise ValueError(f"no path from {src} to {dst}")
    path = [dst]
    while path[-1] != src:
        path.append(int(parent[path[-1]]))
    path.reverse()
    return path


class FleetSim:
    """A fleet of vehicles on shortest-path trips over a road network.

    Parameters
    ----------
    g:           the road network (vertices = intersections).
    fleet_size:  number of vehicles; must leave room to maneuver
                 (``fleet_size < g.n``).
    seed:        RNG seed for initial positions and trip destinations.
    steps_per_tick: streets each vehicle advances per tick (the tick rate
                 knob: 1 simulates dense ticks, larger values sparser ones).
    """

    def __init__(
        self, g: Graph, *, fleet_size: int, seed: int = 0, steps_per_tick: int = 1
    ):
        if not 0 < fleet_size < g.n:
            raise ValueError(f"fleet_size must be in (0, {g.n}), got {fleet_size}")
        if steps_per_tick < 1:
            raise ValueError("steps_per_tick must be >= 1")
        self.g = g
        self.steps_per_tick = int(steps_per_tick)
        self._rng = np.random.default_rng(seed)
        self._pos = [int(v) for v in self._rng.choice(g.n, size=fleet_size, replace=False)]
        self._occupied = set(self._pos)
        # _route[i]: vertices still ahead of vehicle i (current vertex excluded)
        self._routes: list[list[int]] = [[] for _ in range(fleet_size)]
        self._blocked_streak = [0] * fleet_size
        self.ticks = 0
        self.trips_completed = 0
        self.moves_total = 0
        self.blocked_total = 0
        self.reroutes = 0

    @property
    def fleet_size(self) -> int:
        return len(self._pos)

    @property
    def positions(self) -> np.ndarray:
        """Current vehicle vertices, sorted — the engine's object set M."""
        return np.sort(np.asarray(self._pos, dtype=np.int32))

    def _assign_trip(self, i: int) -> None:
        """Draw a fresh destination for vehicle i and route it."""
        src = self._pos[i]
        for _ in range(64):
            dst = int(self._rng.integers(0, self.g.n))
            if dst != src:
                break
        # reversed so the remaining route pops from the tail in O(1)
        self._routes[i] = shortest_path(self.g, src, dst)[1:][::-1]

    def tick(self) -> list[tuple[int, int]]:
        """Advance the fleet one tick; returns the executed (src, dst) moves.

        Vehicles move in a random order each tick (fairness under
        contention); a vehicle whose next vertex is occupied waits. The
        returned moves are in execution order, so staging them sequentially
        through ``QueryEngine.stage_move`` is always valid.
        """
        moves: list[tuple[int, int]] = []
        self.ticks += 1
        for _ in range(self.steps_per_tick):
            for i in self._rng.permutation(self.fleet_size):
                i = int(i)
                if not self._routes[i]:
                    self._assign_trip(i)
                nxt = self._routes[i][-1]
                if nxt in self._occupied:
                    # Blocked. Two vehicles heading into each other would
                    # otherwise deadlock forever (both next-vertices stay
                    # occupied), so after two blocked steps the vehicle gives
                    # up on this trip and routes somewhere else — a detour.
                    self.blocked_total += 1
                    self._blocked_streak[i] += 1
                    if self._blocked_streak[i] >= 2:
                        self._assign_trip(i)
                        self.reroutes += 1
                        self._blocked_streak[i] = 0
                    continue
                self._blocked_streak[i] = 0
                cur = self._pos[i]
                self._occupied.discard(cur)
                self._occupied.add(nxt)
                self._pos[i] = nxt
                self._routes[i].pop()
                if not self._routes[i]:
                    self.trips_completed += 1
                moves.append((cur, nxt))
        self.moves_total += len(moves)
        return moves

    def stats(self) -> dict:
        return {
            "fleet_size": self.fleet_size,
            "ticks": self.ticks,
            "moves_total": self.moves_total,
            "trips_completed": self.trips_completed,
            "blocked_total": self.blocked_total,
            "reroutes": self.reroutes,
        }


def drive_fleet_ticks(engine, tick_moves, *, batch: int, rng, split: bool = False) -> dict:
    """The moving-fleet serving loop shared by serve.py, the road-service
    example and exp12/exp13: for every tick's move batch, stage the movement
    (fused ``stage_move``, or — ``split=True``, the benchmark baseline — a
    delete flush followed by staged inserts), serve one timed query batch,
    then flush. ``tick_moves`` is any iterable of (src, dst) move lists:
    live ``FleetSim.tick()`` calls or a pre-generated trace being replayed.

    The loop is engine-agnostic: ``engine`` is anything exposing the
    ``EngineCore`` serving surface (``stage_move``/``stage_delete``/
    ``stage_insert``, ``flush_updates``, ``query_batch``, ``n``) — the
    scalar ``QueryEngine`` and the multi-device ``ShardedQueryEngine`` are
    driven identically, which is how exp13 compares them on one trace.

    Returns ``{"wall_s", "ticks", "moves", "lat"}`` with ``lat`` the
    per-tick query-batch latencies in seconds (percentile material).
    """
    import time

    import jax

    lat: list[float] = []
    ticks = moves_done = 0
    t0 = time.perf_counter()
    for moves in tick_moves:
        if split:
            for u, _ in moves:
                engine.stage_delete(u)
            engine.flush_updates()
            for _, v in moves:
                engine.stage_insert(v)
        else:
            for u, v in moves:
                engine.stage_move(u, v)
        t1 = time.perf_counter()
        ids, _ = engine.query_batch(rng.integers(0, engine.n, size=batch))
        jax.block_until_ready(ids)
        lat.append(time.perf_counter() - t1)
        engine.flush_updates()
        ticks += 1
        moves_done += len(moves)
    return {
        "wall_s": time.perf_counter() - t0,
        "ticks": ticks,
        "moves": moves_done,
        "lat": lat,
    }
