"""ArchSpec: a selectable architecture = config + per-shape input specs.

Every assigned (arch x shape) cell resolves to a step kind plus a dict of
jax.ShapeDtypeStruct stand-ins (never allocated) — the contract the multi-pod
dry-run lowers against. Smoke tests use make_smoke() reduced configs with real
(tiny) arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str                      # train | prefill | decode | forward | retrieval
    specs: Callable[[Any], dict]   # cfg -> {name: ShapeDtypeStruct or int}
    skip: str | None = None        # non-None => cell skipped, with reason


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys | knn
    make_config: Callable[[], Any]
    make_smoke: Callable[[], Any]
    shapes: dict[str, ShapeCell]


# ---------------------------------------------------------------------------
# LM family shapes (seq_len x global_batch); decode/long lower serve_step
# ---------------------------------------------------------------------------

def lm_shapes(*, full_attention: bool = True) -> dict[str, ShapeCell]:
    def train_4k(cfg):
        return {
            "tokens": SDS((256, 4096), jnp.int32),
            "labels": SDS((256, 4096), jnp.int32),
        }

    def prefill_32k(cfg):
        return {"tokens": SDS((32, 32768), jnp.int32), "max_len": 32768}

    def decode_32k(cfg):
        return {
            "tokens": SDS((128,), jnp.int32),
            "cache_batch": 128,
            "cache_len": 32768,
        }

    def long_500k(cfg):
        return {
            "tokens": SDS((1,), jnp.int32),
            "cache_batch": 1,
            "cache_len": 524288,
        }

    skip = (
        "pure full-attention arch: 512k-token context requires sub-quadratic "
        "attention (see DESIGN.md long_500k note)" if full_attention else None
    )
    return {
        "train_4k": ShapeCell("train", train_4k),
        "prefill_32k": ShapeCell("prefill", prefill_32k),
        "decode_32k": ShapeCell("decode", decode_32k),
        "long_500k": ShapeCell("decode", long_500k, skip=skip),
    }


# ---------------------------------------------------------------------------
# GNN family shapes — one batch layout for all four archs; equivariant models
# get synthesized positions (documented in DESIGN.md). Edge counts are the
# assignment's exact numbers (doubled edges already included in those counts).
# ---------------------------------------------------------------------------

def _pad512(x: int) -> int:
    """Pad irregular graph dims to a 512-device multiple: the data pipeline
    pads with dummy-node self-edges so explicit shardings divide evenly."""
    return ((x + 511) // 512) * 512


def _gnn_specs(n_true: int, e_true: int, d_feat: int, n_classes: int, *, graphs: int = 0):
    n, e = _pad512(n_true), _pad512(e_true)

    def specs(cfg):
        s: dict[str, Any] = {
            "edge_index": SDS((2, e), jnp.int32),
            "pos": SDS((n, 3), jnp.float32),
        }
        if d_feat > 0:
            s["node_feat"] = SDS((n, d_feat), jnp.float32)
        else:
            s["species"] = SDS((n,), jnp.int32)
        if graphs:
            s["graph_id"] = SDS((n,), jnp.int32)
            s["graph_targets"] = SDS((graphs,), jnp.float32)
        else:
            s["labels"] = SDS((n,), jnp.int32)
        return s

    return specs


def gnn_shapes() -> dict[str, ShapeCell]:
    # minibatch_lg: sampled subgraph upper bounds for batch_nodes=1024,
    # fanout 15-10: nodes <= 1024*(1+15+150), edges <= 1024*15*(1+10).
    return {
        "full_graph_sm": ShapeCell("train", _gnn_specs(2708, 10556, 1433, 7)),
        "minibatch_lg": ShapeCell("train", _gnn_specs(169984, 168960, 602, 41)),
        "ogb_products": ShapeCell("train", _gnn_specs(2449029, 61859140, 100, 47)),
        "molecule": ShapeCell("train", _gnn_specs(30 * 128, 64 * 128, 0, 0, graphs=128)),
    }


GNN_SHAPE_META = {
    "full_graph_sm": dict(d_feat=1433, n_classes=7, task="node_class"),
    "minibatch_lg": dict(d_feat=602, n_classes=41, task="node_class"),
    "ogb_products": dict(d_feat=100, n_classes=47, task="node_class"),
    "molecule": dict(d_feat=0, n_classes=1, task="energy"),
}


# ---------------------------------------------------------------------------
# recsys shapes
# ---------------------------------------------------------------------------

def recsys_shapes(n_sparse: int, bag: int) -> dict[str, ShapeCell]:
    def batch(bsz):
        def specs(cfg):
            return {
                "sparse_ids": SDS((bsz, n_sparse, bag), jnp.int32),
                "labels": SDS((bsz,), jnp.int32),
            }
        return specs

    def retrieval(cfg):
        return {
            "sparse_ids": SDS((1, n_sparse, bag), jnp.int32),
            "n_candidates": 1_000_000,
        }

    return {
        "train_batch": ShapeCell("train", batch(65536)),
        "serve_p99": ShapeCell("forward", batch(512)),
        "serve_bulk": ShapeCell("forward", batch(262144)),
        "retrieval_cand": ShapeCell("retrieval", retrieval),
    }
