"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert vocab=202048, MoE 16 experts
top-1, early fusion (modality frontend stubbed per assignment: text tokens).
"""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202048,
        n_experts=16,
        moe_top_k=1,
        param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-scout-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=256,
        n_experts=4,
        moe_top_k=1,
        param_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="llama4-scout-17b-a16e",
    family="lm",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(full_attention=True),
)
