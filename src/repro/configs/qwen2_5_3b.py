"""qwen2.5-3b [hf:Qwen/Qwen2.5 family]. 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936, QKV bias."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_head=128,
        d_ff=11008,
        vocab=151936,
        qkv_bias=True,
        param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=96,
        vocab=128,
        qkv_bias=True,
        param_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="qwen2.5-3b",
    family="lm",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(full_attention=True),
)
