"""gcn-cora [arXiv:1609.02907]. 2 layers, d_hidden=16, mean/sym-norm
aggregation. Per-shape d_feat/classes follow the assigned shape set."""

from repro.configs.common import GNN_SHAPE_META, ArchSpec, gnn_shapes
from repro.models.gnn.gcn import GCNConfig


def make_config(shape: str = "full_graph_sm") -> GCNConfig:
    meta = GNN_SHAPE_META[shape]
    return GCNConfig(
        name="gcn-cora",
        n_layers=2,
        d_hidden=16,
        d_feat=meta["d_feat"],
        n_classes=meta["n_classes"],
        task=meta["task"],
    )


def make_smoke() -> GCNConfig:
    return GCNConfig(name="gcn-smoke", n_layers=2, d_hidden=8, d_feat=12, n_classes=4)


ARCH = ArchSpec(
    arch_id="gcn-cora",
    family="gnn",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=gnn_shapes(),
)
