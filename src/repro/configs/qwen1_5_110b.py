"""qwen1.5-110b [hf:Qwen/Qwen1.5 family]. 80L d_model=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064, QKV bias."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-110b",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=49152,
        vocab=152064,
        qkv_bias=True,
        param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-110b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=128,
        qkv_bias=True,
        param_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="qwen1.5-110b",
    family="lm",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(full_attention=True),
)
