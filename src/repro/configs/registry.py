"""Architecture registry: --arch <id> resolution for launcher/dry-run/tests."""
from __future__ import annotations

from repro.configs import (
    egnn,
    gcn_cora,
    granite_moe_1b_a400m,
    internlm2_20b,
    knn_index,
    llama4_scout_17b_a16e,
    mace,
    nequip,
    qwen1_5_110b,
    qwen2_5_3b,
    xdeepfm,
)
from repro.configs.common import ArchSpec

_ARCHS: dict[str, ArchSpec] = {
    a.arch_id: a
    for a in [
        granite_moe_1b_a400m.ARCH,
        llama4_scout_17b_a16e.ARCH,
        qwen2_5_3b.ARCH,
        internlm2_20b.ARCH,
        qwen1_5_110b.ARCH,
        egnn.ARCH,
        gcn_cora.ARCH,
        nequip.ARCH,
        mace.ARCH,
        xdeepfm.ARCH,
        knn_index.ARCH,
    ]
}

ASSIGNED = [a for a in _ARCHS if a != "knn-index"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(_ARCHS)}")
    return _ARCHS[arch_id]


def all_archs() -> list[ArchSpec]:
    return list(_ARCHS.values())


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) cell; skipped cells carry their skip reason."""
    out = []
    for a in _ARCHS.values():
        for shape, cell in a.shapes.items():
            if cell.skip and not include_skipped:
                continue
            out.append((a, shape, cell))
    return out
