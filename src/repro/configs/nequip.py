"""nequip [arXiv:2101.03164]. 5 layers, 32 channels, l_max=2, 8 RBFs,
cutoff 5, O(3)-equivariant tensor products."""
from repro.configs.common import GNN_SHAPE_META, ArchSpec, gnn_shapes
from repro.models.gnn.nequip import NequIPConfig


def make_config(shape: str = "molecule") -> NequIPConfig:
    meta = GNN_SHAPE_META[shape]
    return NequIPConfig(
        name="nequip",
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        d_feat=meta["d_feat"],
        n_out=1 if meta["task"] == "energy" else meta["n_classes"],
        task=meta["task"],
    )


def make_smoke() -> NequIPConfig:
    return NequIPConfig(
        name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2, n_rbf=4, n_species=4
    )


ARCH = ArchSpec(
    arch_id="nequip",
    family="gnn",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=gnn_shapes(),
)
