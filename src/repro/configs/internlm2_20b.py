"""internlm2-20b [arXiv:2403.17297]. 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92544."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="internlm2-20b",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab=92544,
        param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="internlm2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=4,
        d_head=8,
        d_ff=128,
        vocab=128,
        param_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="internlm2-20b",
    family="lm",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(full_attention=True),
)
