"""mace [arXiv:2206.07697]. 2 layers, 128 channels, l_max=2, correlation
order 3, 8 RBFs, E(3)-ACE higher-order message passing."""
from repro.configs.common import GNN_SHAPE_META, ArchSpec, gnn_shapes
from repro.models.gnn.mace import MACEConfig


def make_config(shape: str = "molecule") -> MACEConfig:
    meta = GNN_SHAPE_META[shape]
    return MACEConfig(
        name="mace",
        n_layers=2,
        d_hidden=128,
        l_max=2,
        correlation_order=3,
        n_rbf=8,
        cutoff=5.0,
        d_feat=meta["d_feat"],
        n_out=1 if meta["task"] == "energy" else meta["n_classes"],
        task=meta["task"],
    )


def make_smoke() -> MACEConfig:
    return MACEConfig(
        name="mace-smoke", n_layers=2, d_hidden=8, l_max=2, correlation_order=3,
        n_rbf=4, n_species=4
    )


ARCH = ArchSpec(
    arch_id="mace",
    family="gnn",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=gnn_shapes(),
)
