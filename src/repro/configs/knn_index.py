"""The paper's own architecture: KNN-Index over a USA-scale road network.

Two production cells (in addition to the 40 assigned cells):
  build_sweep : one level-synchronous construction step at full scale
                (n = 2^24 vertices ~ USA's 23.9M, k = 20 = the paper's
                default, level batch 131072, tau = 32 > every Table-2 tau)
  serve_batch : 2^20 concurrent kNN queries against the sharded index
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import SDS, ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class KNNIndexConfig:
    name: str
    n_vertices: int = 1 << 24
    k: int = 20
    level_batch: int = 131072
    tau: int = 32
    query_batch: int = 1 << 20


def make_config() -> KNNIndexConfig:
    return KNNIndexConfig(name="knn-index-usa")


def make_smoke() -> KNNIndexConfig:
    return KNNIndexConfig(
        name="knn-index-smoke", n_vertices=512, k=5, level_batch=64, tau=4, query_batch=32
    )


def _rows(n: int) -> int:
    """Index rows incl. the dummy pad row, padded to a 512-device multiple."""
    return ((n + 1 + 511) // 512) * 512


def _build_specs(cfg: KNNIndexConfig):
    s, t, k = cfg.level_batch, cfg.tau, cfg.k
    rows = _rows(cfg.n_vertices)
    return {
        "verts": SDS((s,), jnp.int32),
        "nbr": SDS((s, t), jnp.int32),
        "w": SDS((s, t), jnp.float32),
        "extra_ids": SDS((s, k), jnp.int32),
        "extra_d": SDS((s, k), jnp.float32),
        "vk_ids": SDS((rows, k), jnp.int32),
        "vk_d": SDS((rows, k), jnp.float32),
    }


def _serve_specs(cfg: KNNIndexConfig):
    rows = _rows(cfg.n_vertices)
    return {
        "vk_ids": SDS((rows, cfg.k), jnp.int32),
        "vk_d": SDS((rows, cfg.k), jnp.float32),
        "queries": SDS((cfg.query_batch,), jnp.int32),
    }


ARCH = ArchSpec(
    arch_id="knn-index",
    family="knn",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes={
        "build_sweep": ShapeCell("knn_build", _build_specs),
        "serve_batch": ShapeCell("knn_serve", _serve_specs),
    },
)
