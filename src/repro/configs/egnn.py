"""egnn [arXiv:2102.09844]. 4 layers, d_hidden=64, E(n)-equivariant."""
from repro.configs.common import GNN_SHAPE_META, ArchSpec, gnn_shapes
from repro.models.gnn.egnn import EGNNConfig


def make_config(shape: str = "molecule") -> EGNNConfig:
    meta = GNN_SHAPE_META[shape]
    return EGNNConfig(
        name="egnn",
        n_layers=4,
        d_hidden=64,
        d_feat=meta["d_feat"],
        n_out=1 if meta["task"] == "energy" else meta["n_classes"],
        task=meta["task"],
    )


def make_smoke() -> EGNNConfig:
    return EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_feat=8, n_out=1)


ARCH = ArchSpec(
    arch_id="egnn",
    family="gnn",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=gnn_shapes(),
)
