"""xdeepfm [arXiv:1803.05170]. 39 sparse fields, embed_dim=10,
CIN 200-200-200, MLP 400-400. Tables: 10^6 rows per field (row-sharded)."""
from repro.configs.common import ArchSpec, recsys_shapes
from repro.models.recsys import XDeepFMConfig

_BAG = 3


def make_config() -> XDeepFMConfig:
    return XDeepFMConfig(
        name="xdeepfm",
        n_sparse=39,
        embed_dim=10,
        table_rows=1_000_000,
        cin_layers=(200, 200, 200),
        mlp_layers=(400, 400),
        multi_hot_fields=4,
        bag_size=_BAG,
    )


def make_smoke() -> XDeepFMConfig:
    return XDeepFMConfig(
        name="xdeepfm-smoke",
        n_sparse=6,
        embed_dim=4,
        table_rows=64,
        cin_layers=(8, 8),
        mlp_layers=(16,),
        bag_size=_BAG,
    )


ARCH = ArchSpec(
    arch_id="xdeepfm",
    family="recsys",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=recsys_shapes(39, _BAG),
)
