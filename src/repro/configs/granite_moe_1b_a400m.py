"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32 experts
top-8.
"""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-1b-a400m",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=64,
        d_ff=512,
        vocab=49155,
        n_experts=32,
        moe_top_k=8,
        param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=32,
        vocab=128,
        n_experts=4,
        moe_top_k=2,
        param_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="granite-moe-1b-a400m",
    family="lm",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(full_attention=True),
)
