"""Synthetic road-network generators.

The container is offline, so the DIMACS road networks from the paper are not
available. Road networks are near-planar, low-degree (avg deg ~2.5-3.5),
locally meshy graphs; we generate grid-based networks with random edge
deletions, diagonal shortcuts and distance-like weights, which match the
structural statistics (small eta/tau/rho, Table 2) that the paper's algorithms
exploit.
"""
from __future__ import annotations

import numpy as np

from .csr import Graph, from_edges, is_connected


def road_network(
    nx: int,
    ny: int,
    *,
    seed: int = 0,
    delete_frac: float = 0.18,
    diag_frac: float = 0.08,
    weight_low: float = 1.0,
    weight_high: float = 10.0,
    integer_weights: bool = True,
) -> Graph:
    """Grid-city road network: nx*ny intersections, Manhattan-ish streets.

    Edges get physical-distance-like weights; a fraction of streets is removed
    (keeping the network connected) and a few diagonal connectors added, which
    reproduces the low-treewidth, small-separator structure of real road nets.
    """
    rng = np.random.default_rng(seed)
    n = nx * ny
    vid = lambda x, y: x * ny + y

    edges: list[tuple[int, int, float]] = []
    for x in range(nx):
        for y in range(ny):
            if x + 1 < nx:
                edges.append((vid(x, y), vid(x + 1, y), 0.0))
            if y + 1 < ny:
                edges.append((vid(x, y), vid(x, y + 1), 0.0))

    # Random deletions, preserving connectivity via a kept spanning tree.
    edges_arr = np.array([(u, v) for u, v, _ in edges], dtype=np.int64)
    perm = rng.permutation(len(edges_arr))
    parent = np.arange(n)

    def find(a: int) -> int:
        root = a
        while parent[root] != root:
            root = parent[root]
        while parent[a] != root:
            parent[a], a = root, parent[a]
        return root

    in_tree = np.zeros(len(edges_arr), dtype=bool)
    for idx in perm:
        u, v = edges_arr[idx]
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv
            in_tree[idx] = True

    deletable = np.flatnonzero(~in_tree)
    n_del = int(delete_frac * len(edges_arr))
    to_del = set(rng.choice(deletable, size=min(n_del, len(deletable)), replace=False).tolist())
    kept = [(int(edges_arr[i, 0]), int(edges_arr[i, 1])) for i in range(len(edges_arr)) if i not in to_del]

    # Diagonal connectors.
    n_diag = int(diag_frac * n)
    for _ in range(n_diag):
        x = int(rng.integers(0, nx - 1))
        y = int(rng.integers(0, ny - 1))
        if rng.random() < 0.5:
            kept.append((vid(x, y), vid(x + 1, y + 1)))
        else:
            kept.append((vid(x + 1, y), vid(x, y + 1)))

    ws = rng.uniform(weight_low, weight_high, size=len(kept))
    if integer_weights:
        ws = np.maximum(1.0, np.round(ws))
    g = from_edges(n, [(u, v, float(w)) for (u, v), w in zip(kept, ws)])
    assert is_connected(g), "generator must produce a connected network"
    return g


def random_connected_graph(
    n: int, extra_edges: int, *, seed: int = 0, weight_low: float = 1.0, weight_high: float = 20.0
) -> Graph:
    """Random connected graph: random spanning tree + extra random edges.

    Used by property-based tests (small n, arbitrary topology).
    """
    rng = np.random.default_rng(seed)
    edges: list[tuple[int, int, float]] = []
    order = rng.permutation(n)
    for i in range(1, n):
        j = int(rng.integers(0, i))
        edges.append((int(order[i]), int(order[j]), 0.0))
    for _ in range(extra_edges):
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u != v:
            edges.append((u, v, 0.0))
    ws = np.maximum(1.0, np.round(rng.uniform(weight_low, weight_high, size=len(edges))))
    return from_edges(n, [(u, v, float(w)) for (u, v, _), w in zip(edges, ws)])


def pick_objects(n: int, mu: float, *, seed: int = 0) -> np.ndarray:
    """Candidate object set M: random vertices at density mu=|M|/|V| (paper §7)."""
    rng = np.random.default_rng(seed)
    size = max(1, int(round(mu * n)))
    return np.sort(rng.choice(n, size=size, replace=False)).astype(np.int32)
