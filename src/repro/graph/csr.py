"""CSR graph substrate for road networks.

Undirected weighted graphs stored in CSR form. All the paper's structures
(BN-Graph, KNN-Index) are built on top of this representation; the JAX layers
consume the padded-dense views derived from it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected weighted graph in CSR form (each edge stored twice)."""

    n: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (2m,) int32 neighbor ids
    weights: np.ndarray  # (2m,) float64 edge weights

    @property
    def m(self) -> int:
        return int(self.indices.shape[0] // 2)

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[v], self.indptr[v + 1]
        return self.indices[s:e], self.weights[s:e]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(u, v, w) with u < v, each undirected edge once."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        mask = src < self.indices
        return src[mask], self.indices[mask], self.weights[mask]

    def adjacency_dicts(self) -> list[dict[int, float]]:
        """Mutable dict-of-dicts adjacency (used by the elimination passes)."""
        adj: list[dict[int, float]] = [dict() for _ in range(self.n)]
        for v in range(self.n):
            s, e = self.indptr[v], self.indptr[v + 1]
            for u, w in zip(self.indices[s:e].tolist(), self.weights[s:e].tolist()):
                old = adj[v].get(u)
                if old is None or w < old:
                    adj[v][u] = w
        return adj

    def to_dense_padded(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded (n, dmax) neighbor/weight tables; pad id = -1, pad w = +inf."""
        deg = self.degrees()
        dmax = int(deg.max()) if self.n else 0
        nbr = np.full((self.n, dmax), -1, dtype=np.int32)
        wts = np.full((self.n, dmax), np.inf, dtype=np.float64)
        for v in range(self.n):
            s, e = self.indptr[v], self.indptr[v + 1]
            nbr[v, : e - s] = self.indices[s:e]
            wts[v, : e - s] = self.weights[s:e]
        return nbr, wts, deg


def from_edges(n: int, edges: Iterable[tuple[int, int, float]]) -> Graph:
    """Build a Graph from an iterable of (u, v, w); parallel edges keep min w."""
    best: dict[tuple[int, int], float] = {}
    for u, v, w in edges:
        if u == v:
            continue
        key = (u, v) if u < v else (v, u)
        old = best.get(key)
        if old is None or w < old:
            best[key] = float(w)
    us = np.empty(2 * len(best), dtype=np.int32)
    vs = np.empty(2 * len(best), dtype=np.int32)
    ws = np.empty(2 * len(best), dtype=np.float64)
    for i, ((u, v), w) in enumerate(best.items()):
        us[2 * i], vs[2 * i], ws[2 * i] = u, v, w
        us[2 * i + 1], vs[2 * i + 1], ws[2 * i + 1] = v, u, w
    order = np.lexsort((vs, us))
    us, vs, ws = us[order], vs[order], ws[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, us + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n=n, indptr=indptr, indices=vs, weights=ws)


def from_adjacency_dicts(adj: Sequence[dict[int, float]]) -> Graph:
    n = len(adj)
    edges = []
    for u, nbrs in enumerate(adj):
        for v, w in nbrs.items():
            if u < v:
                edges.append((u, v, w))
    return from_edges(n, edges)


def is_connected(g: Graph) -> bool:
    if g.n == 0:
        return True
    seen = np.zeros(g.n, dtype=bool)
    stack = [0]
    seen[0] = True
    count = 1
    while stack:
        v = stack.pop()
        nbrs, _ = g.neighbors(v)
        for u in nbrs:
            if not seen[u]:
                seen[u] = True
                count += 1
                stack.append(int(u))
    return count == g.n
