"""CSR graph substrate for road networks.

Undirected weighted graphs stored in CSR form. All the paper's structures
(BN-Graph, KNN-Index) are built on top of this representation; the JAX layers
consume the padded-dense views derived from it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected weighted graph in CSR form (each edge stored twice)."""

    n: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (2m,) int32 neighbor ids
    weights: np.ndarray  # (2m,) float64 edge weights

    @property
    def m(self) -> int:
        return int(self.indices.shape[0] // 2)

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[v], self.indptr[v + 1]
        return self.indices[s:e], self.weights[s:e]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(u, v, w) with u < v, each undirected edge once."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        mask = src < self.indices
        return src[mask], self.indices[mask], self.weights[mask]

    def adjacency_dicts(self) -> list[dict[int, float]]:
        """Mutable dict-of-dicts adjacency (used by the elimination passes)."""
        adj: list[dict[int, float]] = [dict() for _ in range(self.n)]
        for v in range(self.n):
            s, e = self.indptr[v], self.indptr[v + 1]
            for u, w in zip(self.indices[s:e].tolist(), self.weights[s:e].tolist()):
                old = adj[v].get(u)
                if old is None or w < old:
                    adj[v][u] = w
        return adj

    def to_dense_padded(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded (n, dmax) neighbor/weight tables; pad id = -1, pad w = +inf."""
        deg = self.degrees()
        dmax = int(deg.max()) if self.n else 0
        nbr = np.full((self.n, dmax), -1, dtype=np.int32)
        wts = np.full((self.n, dmax), np.inf, dtype=np.float64)
        for v in range(self.n):
            s, e = self.indptr[v], self.indptr[v + 1]
            nbr[v, : e - s] = self.indices[s:e]
            wts[v, : e - s] = self.weights[s:e]
        return nbr, wts, deg


@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """Dual padded-dense + CSR view of a (possibly non-simple) adjacency.

    The padded form is what device gathers consume: ``ids``/``w`` are
    ``(n+1, t)`` with valid neighbors compacted to the front of each row
    (a row of degree d is fully described by its first d columns), ``-1`` /
    ``+inf`` pads behind them, and a trailing all-pad dummy row so batched
    row gathers can clamp padding to row ``n``. The CSR triple
    (``indptr``, ``indices``, ``weights``) is the same adjacency without
    padding, for host-side set algebra (frontier expansion, audits).
    Weights are float32 — the dtype the device pipelines run in.
    """

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32
    weights: np.ndarray  # (nnz,) float32
    ids: np.ndarray      # (n+1, t) int32, -1 padded, valid-first per row
    w: np.ndarray        # (n+1, t) float32, +inf on pads
    deg: np.ndarray      # (n+1,) int32 per-row valid count (dummy row: 0)

    def relayout_rows(self, padded_rows: int, row_of_v: np.ndarray) -> np.ndarray:
        """Neighbor-id table re-laid into a partitioned row layout.

        ``row_of_v`` maps vertex v to its row in a ``padded_rows``-row
        partitioned layout (the sharded engine's vertex -> global-padded-row
        map); the result holds vertex v's padded neighbor ids at
        ``row_of_v[v]`` and all ``-1`` on the layout's pad rows — the
        per-shard CSR slice the device receiver-set expansion gathers from.
        """
        out = np.full((padded_rows, self.ids.shape[1]), -1, np.int32)
        out[np.asarray(row_of_v, np.int64)] = self.ids[: self.n]
        return out


def padded_csr(ids: np.ndarray, w: np.ndarray) -> PaddedCSR:
    """Build a ``PaddedCSR`` from raw padded ``(n, t)`` id/weight tables.

    Input rows may hold ``-1`` pads anywhere; the output compacts valid
    entries to the front (stable, preserving input column order), derives
    the CSR triple from the compacted rows and appends the dummy row.
    """
    ids = np.asarray(ids, dtype=np.int32)
    w = np.asarray(w, dtype=np.float32).copy()
    n = ids.shape[0]
    w[ids < 0] = np.inf
    order = np.argsort(ids < 0, axis=1, kind="stable")  # valid entries first
    ids = np.take_along_axis(ids, order, axis=1)
    w = np.take_along_axis(w, order, axis=1)
    deg = (ids >= 0).sum(axis=1).astype(np.int32)
    valid = ids >= 0
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    ids_p = np.concatenate([ids, np.full((1, ids.shape[1]), -1, np.int32)])
    w_p = np.concatenate([w, np.full((1, w.shape[1]), np.inf, np.float32)])
    return PaddedCSR(
        n=n,
        indptr=indptr,
        indices=ids[valid].ravel(),
        weights=w[valid].ravel(),
        ids=ids_p,
        w=w_p,
        deg=np.concatenate([deg, np.zeros(1, np.int32)]),
    )


def from_edges(n: int, edges: Iterable[tuple[int, int, float]]) -> Graph:
    """Build a Graph from an iterable of (u, v, w); parallel edges keep min w."""
    best: dict[tuple[int, int], float] = {}
    for u, v, w in edges:
        if u == v:
            continue
        key = (u, v) if u < v else (v, u)
        old = best.get(key)
        if old is None or w < old:
            best[key] = float(w)
    us = np.empty(2 * len(best), dtype=np.int32)
    vs = np.empty(2 * len(best), dtype=np.int32)
    ws = np.empty(2 * len(best), dtype=np.float64)
    for i, ((u, v), w) in enumerate(best.items()):
        us[2 * i], vs[2 * i], ws[2 * i] = u, v, w
        us[2 * i + 1], vs[2 * i + 1], ws[2 * i + 1] = v, u, w
    order = np.lexsort((vs, us))
    us, vs, ws = us[order], vs[order], ws[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, us + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n=n, indptr=indptr, indices=vs, weights=ws)


def from_adjacency_dicts(adj: Sequence[dict[int, float]]) -> Graph:
    n = len(adj)
    edges = []
    for u, nbrs in enumerate(adj):
        for v, w in nbrs.items():
            if u < v:
                edges.append((u, v, w))
    return from_edges(n, edges)


def is_connected(g: Graph) -> bool:
    if g.n == 0:
        return True
    seen = np.zeros(g.n, dtype=bool)
    stack = [0]
    seen[0] = True
    count = 1
    while stack:
        v = stack.pop()
        nbrs, _ = g.neighbors(v)
        for u in nbrs:
            if not seen[u]:
                seen[u] = True
                count += 1
                stack.append(int(u))
    return count == g.n
