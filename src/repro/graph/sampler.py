"""K-hop neighbor sampling over CSR graphs (the `minibatch_lg` substrate).

GraphSAGE-style uniform fanout sampling (arXiv:1706.02216): per layer, each
frontier node samples up to `fanout` neighbors without replacement. Runs on
the host data-pipeline workers (random gather over CSR is host work at every
production shop); the sampled subgraph ships to devices as padded edge
arrays compatible with the GNN train step.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import Graph


@dataclasses.dataclass
class SampledSubgraph:
    nodes: np.ndarray        # (n_sub,) original node ids (position = local id)
    edge_index: np.ndarray   # (2, e_sub) local ids, dst = aggregation target
    seeds_local: np.ndarray  # (batch,) local ids of the seed nodes


def sample_khop(
    g: Graph, seeds: np.ndarray, fanouts: tuple[int, ...], *, seed: int = 0
) -> SampledSubgraph:
    rng = np.random.default_rng(seed)
    node_ids: list[int] = list(dict.fromkeys(seeds.tolist()))
    local = {v: i for i, v in enumerate(node_ids)}
    edges_src: list[int] = []
    edges_dst: list[int] = []
    frontier = list(node_ids)
    for fanout in fanouts:
        nxt: list[int] = []
        for v in frontier:
            nbrs, _ = g.neighbors(v)
            if len(nbrs) == 0:
                continue
            take = min(fanout, len(nbrs))
            picked = rng.choice(nbrs, size=take, replace=False)
            for u in picked.tolist():
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                    nxt.append(u)
                # message u -> v (aggregate into the frontier node)
                edges_src.append(local[u])
                edges_dst.append(local[v])
        frontier = nxt
        if not frontier:
            break
    return SampledSubgraph(
        nodes=np.asarray(node_ids, dtype=np.int64),
        edge_index=np.asarray([edges_src, edges_dst], dtype=np.int32),
        seeds_local=np.asarray([local[int(s)] for s in seeds], dtype=np.int32),
    )


def pad_subgraph(sub: SampledSubgraph, n_nodes_pad: int, n_edges_pad: int) -> SampledSubgraph:
    """Pad to static shapes (dummy node = last slot, self-edges as padding)."""
    n = len(sub.nodes)
    e = sub.edge_index.shape[1]
    assert n <= n_nodes_pad and e <= n_edges_pad, (n, n_nodes_pad, e, n_edges_pad)
    nodes = np.concatenate([sub.nodes, np.zeros(n_nodes_pad - n, np.int64)])
    dummy = n_nodes_pad - 1
    pad_e = np.full((2, n_edges_pad - e), dummy, np.int32)
    return SampledSubgraph(
        nodes=nodes,
        edge_index=np.concatenate([sub.edge_index, pad_e], axis=1),
        seeds_local=sub.seeds_local,
    )
