"""MACE (Batatia et al., arXiv:2206.07697): higher-order equivariant message
passing through the Atomic Cluster Expansion.

Assigned config: 2 layers, 128 channels, l_max=2, correlation order 3,
8 Bessel RBFs. Each layer builds the A-basis (one tensor-product interaction
aggregated over edges) and then the B-basis by channel-wise symmetric CG
powers of A up to order 3 with learnable per-(path, channel) weights — this
is what lifts the message body order beyond pairwise without extra graph
passes (the paper's core idea).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common, irreps


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    d_feat: int = 0
    n_out: int = 1
    task: str = "energy"
    param_dtype: object = jnp.float32


def _paths(cfg):
    return irreps.cg_paths(cfg.l_max)


def init_params(rng, cfg: MACEConfig) -> dict:
    c = cfg.d_hidden
    paths = _paths(cfg)
    ks = jax.random.split(rng, cfg.n_layers * 6 + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = ks[6 * i : 6 * i + 6]
        lin = lambda key, l_set: {
            str(l): (jax.random.normal(jax.random.fold_in(key, l), (c, c)) / c**0.5).astype(cfg.param_dtype)
            for l in l_set
        }
        ls = range(cfg.l_max + 1)
        layers.append(
            {
                "radial": common.mlp_init(kk[0], [cfg.n_rbf, 64, len(paths) * c], cfg.param_dtype),
                "lin_pre": lin(kk[1], ls),
                # per-path per-channel weights for the order-2 / order-3 products
                "w2": {f"{a}_{b}_{o}": (jax.random.normal(jax.random.fold_in(kk[2], 100 * a + 10 * b + o), (c,)) * 0.3).astype(cfg.param_dtype)
                        for (a, b, o) in paths},
                "w3": {f"{a}_{b}_{o}": (jax.random.normal(jax.random.fold_in(kk[3], 100 * a + 10 * b + o), (c,)) * 0.3).astype(cfg.param_dtype)
                        for (a, b, o) in paths},
                "lin_msg": lin(kk[4], ls),
                "lin_res": lin(kk[5], ls),
            }
        )
    if cfg.d_feat > 0:
        enc = common.mlp_init(ks[-3], [cfg.d_feat, c], cfg.param_dtype)
    else:
        enc = (jax.random.normal(ks[-3], (cfg.n_species, c)) * 0.5).astype(cfg.param_dtype)
    return {
        "encoder": enc,
        "layers": layers,
        "readout": common.mlp_init(ks[-1], [c, c, cfg.n_out], cfg.param_dtype),
    }


def _sym_power(a: dict, w_tab: dict, cfg, base: dict) -> dict:
    """One channel-wise CG power step: out[l3] = sum_paths w * CG(a[l1] x base[l2])."""
    out: dict[int, jax.Array] = {}
    for (l1, l2, l3) in _paths(cfg):
        if l1 not in a or l2 not in base:
            continue
        w = w_tab[f"{l1}_{l2}_{l3}"]
        c = jnp.asarray(irreps.real_cg(l1, l2, l3), a[l1].dtype)
        y = jnp.einsum("nka,nkb,abm->nkm", a[l1], base[l2], c) * w[None, :, None].astype(a[l1].dtype)
        out[l3] = out.get(l3, 0) + y
    return out


def forward(params, batch, cfg: MACEConfig):
    src, dst = batch["edge_index"]
    pos = batch["pos"]
    n = pos.shape[0]
    c = cfg.d_hidden
    rel = pos[dst] - pos[src]
    r = jnp.linalg.norm(rel, axis=-1)
    rbf = irreps.bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    ylm = irreps.sh(rel, cfg.l_max)
    paths = _paths(cfg)

    if cfg.d_feat > 0:
        s = common.mlp_apply(
            params["encoder"], batch["node_feat"].astype(cfg.param_dtype), final_act=True
        )
    else:
        s = params["encoder"][batch["species"]]
    s = s.astype(cfg.param_dtype)
    rbf = rbf.astype(cfg.param_dtype)
    ylm = {l: y.astype(cfg.param_dtype) for l, y in ylm.items()}
    feats = {0: s[:, :, None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, c, 2 * l + 1), s.dtype)

    site_energies = 0.0
    for lp in params["layers"]:
        h = irreps.linear_mix(feats, {int(l): w for l, w in lp["lin_pre"].items()})
        radial = common.mlp_apply(lp["radial"], rbf).reshape(-1, len(paths), c)
        src_feats = {l: x[src] for l, x in h.items()}
        path_w = {p: radial[:, i, :] for i, p in enumerate(paths)}
        msgs = irreps.tensor_product(src_feats, ylm, path_w, cfg.l_max)
        # A-basis: aggregated one-particle basis
        a_basis = {
            l: common.scatter_sum(m.reshape(m.shape[0], -1), dst, n).reshape(n, c, 2 * l + 1)
            for l, m in msgs.items()
        }
        # B-basis: symmetric channel-wise powers (correlation order 3)
        b = {l: a_basis[l] for l in a_basis}
        prod = a_basis
        if cfg.correlation_order >= 2:
            prod = _sym_power(prod, lp["w2"], cfg, a_basis)
            for l, x in prod.items():
                b[l] = b.get(l, 0) + x
        if cfg.correlation_order >= 3:
            prod = _sym_power(prod, lp["w3"], cfg, a_basis)
            for l, x in prod.items():
                b[l] = b.get(l, 0) + x
        m = irreps.linear_mix(b, {int(l): w for l, w in lp["lin_msg"].items()})
        res = irreps.linear_mix(feats, {int(l): w for l, w in lp["lin_res"].items()})
        feats = {l: m.get(l, 0) + res.get(l, 0) for l in feats}
        site_energies = site_energies + common.mlp_apply(params["readout"], feats[0][:, :, 0])
    return site_energies


def loss_fn(params, batch, cfg: MACEConfig) -> jax.Array:
    out = forward(params, batch, cfg)
    if cfg.task == "energy":
        n_graphs = batch["graph_targets"].shape[0]
        energy = jax.ops.segment_sum(out[:, 0], batch["graph_id"], num_segments=n_graphs)
        err = energy - batch["graph_targets"]
        return jnp.mean(err * err)
    lg = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lg, batch["labels"][:, None], axis=1))
