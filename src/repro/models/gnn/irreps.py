"""Real-spherical-harmonic irreps algebra for E(3)-equivariant GNNs.

Features carry a dict {l: array[..., C, 2l+1]}. Clebsch-Gordan tensors for the
real basis are generated numerically at import time (l <= 2 needed for the
assigned NequIP/MACE configs): complex CG via the Racah formula, conjugated
into the real harmonic basis, phase-fixed to be real.

Conventions: real l=1 components are ordered (y, z, x) (e3nn convention), so
sh_l1(v) = (y, z, x)/|v|. Wigner matrices for l>=2 are derived from the CG
recursion D_l = C^T (D_{l-1} x D_1) C, which the equivariance tests use.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

L_MAX = 2


def _su2_cg(j1: float, m1: float, j2: float, m2: float, j3: float, m3: float) -> float:
    """Complex <j1 m1 j2 m2 | j3 m3> via the Racah formula."""
    if m3 != m1 + m2 or not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    f = lambda x: math.factorial(int(round(x)))
    pre = (2 * j3 + 1) * f(j1 + j2 - j3) * f(j1 - j2 + j3) * f(-j1 + j2 + j3) / f(j1 + j2 + j3 + 1)
    pre *= f(j3 + m3) * f(j3 - m3) * f(j1 - m1) * f(j1 + m1) * f(j2 - m2) * f(j2 + m2)
    s = 0.0
    for k in range(0, int(j1 + j2 + j3) + 2):
        t = [k, j1 + j2 - j3 - k, j1 - m1 - k, j2 + m2 - k, j3 - j2 + m1 + k, j3 - j1 - m2 + k]
        if any(x < 0 for x in t):
            continue
        s += (-1) ** k / math.prod(f(x) for x in t)
    return math.sqrt(pre) * s


def _real_basis(l: int) -> np.ndarray:
    """U[m_real, m_complex]: complex->real harmonic change of basis."""
    dim = 2 * l + 1
    u = np.zeros((dim, dim), dtype=complex)
    for m in range(-l, l + 1):
        i = m + l
        if m > 0:
            u[i, -m + l] = 1 / math.sqrt(2)
            u[i, m + l] = (-1) ** m / math.sqrt(2)
        elif m == 0:
            u[i, l] = 1.0
        else:
            am = -m
            u[i, -am + l] = 1j / math.sqrt(2)
            u[i, am + l] = -1j * (-1) ** am / math.sqrt(2)
    return u


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C[(2l1+1), (2l2+1), (2l3+1)], orthonormal in c."""
    u1, u2, u3 = _real_basis(l1), _real_basis(l2), _real_basis(l3)
    cg = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=complex)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                cg[m1 + l1, m2 + l2, m3 + l3] = _su2_cg(l1, m1, l2, m2, l3, m3)
    c = np.einsum("au,bv,cw,uvw->abc", np.conj(u1), np.conj(u2), u3, cg)
    # phase-fix: the result is either purely real or purely imaginary
    if np.abs(c.imag).max() > np.abs(c.real).max():
        c = (c * (-1j))
    assert np.abs(c.imag).max() < 1e-10, (l1, l2, l3, np.abs(c.imag).max())
    return np.ascontiguousarray(c.real)


def cg_paths(l_max: int = L_MAX):
    """All (l1, l2, l3) with nonzero CG and every l <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out


def wigner_d(l: int, r: np.ndarray) -> np.ndarray:
    """Wigner D-matrix for rotation r (3x3) in the real basis, via recursion."""
    q = np.zeros((3, 3))
    q[0, 1], q[1, 2], q[2, 0] = 1, 1, 1  # (x,y,z) -> (y,z,x)
    if l == 0:
        return np.ones((1, 1))
    d1 = q @ r @ q.T
    if l == 1:
        return d1
    d_prev = wigner_d(l - 1, r)
    c = real_cg(l - 1, 1, l).reshape((2 * l - 1) * 3, 2 * l + 1)
    return c.T @ np.kron(d_prev, d1) @ c


# ---------------------------------------------------------------------------
# jnp-side irreps ops
# ---------------------------------------------------------------------------

def sh(v: jax.Array, l_max: int = L_MAX, eps: float = 1e-9) -> dict[int, jax.Array]:
    """Real spherical harmonics of directions v (..., 3), unit-normalised.

    Returns {l: (..., 2l+1)}; l=0 constant 1, l=1 = (y,z,x)/|v|, higher l by
    CG recursion (renormalised to unit norm on the sphere)."""
    n = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), eps)
    out = {0: jnp.ones(v.shape[:-1] + (1,), v.dtype)}
    y1 = jnp.stack([n[..., 1], n[..., 2], n[..., 0]], axis=-1)
    if l_max >= 1:
        out[1] = y1
    prev = y1
    for l in range(2, l_max + 1):
        c = jnp.asarray(real_cg(l - 1, 1, l), v.dtype)
        yl = jnp.einsum("...a,...b,abc->...c", prev, y1, c)
        # normalise to unit norm (the norm is direction-independent for exact CG)
        yl = yl / jnp.maximum(jnp.linalg.norm(yl, axis=-1, keepdims=True), eps)
        out[l] = yl
        prev = yl
    return out


def linear_mix(feats: dict[int, jax.Array], weights: dict[int, jax.Array]) -> dict[int, jax.Array]:
    """Per-l channel mixing: weights[l] (C_in, C_out)."""
    return {
        l: jnp.einsum("...ci,co->...oi", x, weights[l].astype(x.dtype))
        for l, x in feats.items()
        if l in weights
    }


def tensor_product(
    f1: dict[int, jax.Array],
    f2: dict[int, jax.Array],
    path_w: dict[tuple[int, int, int], jax.Array],
    l_max: int = L_MAX,
) -> dict[int, jax.Array]:
    """Channel-wise weighted CG tensor product.

    f1[l1]: (..., C, 2l1+1); f2[l2]: (..., 2l2+1) (single-channel filter, e.g.
    spherical harmonics) or (..., C, 2l2+1); path_w[(l1,l2,l3)]: (..., C).
    """
    out: dict[int, jax.Array] = {}
    for (l1, l2, l3), w in path_w.items():
        if l1 not in f1 or l2 not in f2:
            continue
        c = jnp.asarray(real_cg(l1, l2, l3), f1[l1].dtype)
        x2 = f2[l2]
        if x2.ndim == f1[l1].ndim:  # (..., C, 2l2+1)
            y = jnp.einsum("...ka,...kb,abm->...km", f1[l1], x2, c)
        else:
            y = jnp.einsum("...ka,...b,abm->...km", f1[l1], x2, c)
        y = y * w[..., None].astype(y.dtype)
        out[l3] = out.get(l3, 0) + y
    return out


def gate(feats: dict[int, jax.Array], act=jax.nn.silu) -> dict[int, jax.Array]:
    """Gated nonlinearity: scalars through act; l>0 scaled by act(scalar gate)."""
    out = {0: act(feats[0])}
    if len(feats) > 1:
        g = jax.nn.sigmoid(feats[0].mean(axis=-1, keepdims=True))
        for l, x in feats.items():
            if l > 0:
                out[l] = x * g[..., None] if g.ndim == x.ndim - 1 else x * g
    return out


def bessel_rbf(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Bessel radial basis with cosine cutoff envelope. r (...,) -> (..., n_rbf)."""
    rc = jnp.clip(r, 1e-6, cutoff)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rc[..., None] / cutoff) / rc[..., None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r, 0, cutoff) / cutoff) + 1.0)
    return basis * env[..., None]
