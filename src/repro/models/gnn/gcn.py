"""GCN (Kipf & Welling, arXiv:1609.02907) — symmetric-normalised mean
aggregation, the assigned gcn-cora config (2 layers, hidden 16)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433          # 0 -> species-embedding input
    n_classes: int = 7
    n_species: int = 16
    task: str = "node_class"    # "node_class" | "energy"
    param_dtype: object = jnp.float32


def init_params(rng, cfg: GCNConfig) -> dict:
    d0 = cfg.d_feat if cfg.d_feat > 0 else cfg.d_hidden
    dims = [d0] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(rng, len(dims) + 1)
    p = {
        "layers": [
            {"w": (jax.random.normal(k, (a, b)) / a**0.5).astype(cfg.param_dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])
        ]
    }
    if cfg.d_feat == 0:
        p["embed"] = (jax.random.normal(ks[-1], (cfg.n_species, d0)) * 0.5).astype(cfg.param_dtype)
    return p


def forward(params, batch, cfg: GCNConfig) -> jax.Array:
    """batch: node_feat (n, d_feat) or species (n,); edge_index (2, E)."""
    x = batch["node_feat"] if cfg.d_feat > 0 else params["embed"][batch["species"]]
    src, dst = batch["edge_index"]
    n = x.shape[0]
    deg = common.degree(dst, n, x.dtype) + 1.0  # +1: self loop normalisation
    norm = jax.lax.rsqrt(deg)
    coef = (norm[src] * norm[dst])[:, None]
    for i, layer in enumerate(params["layers"]):
        h = x @ layer["w"].astype(x.dtype)
        msg = h[src] * coef
        agg = common.scatter_sum(msg, dst, n) + h * (norm**2)[:, None]  # self loop
        x = jax.nn.relu(agg) if i < len(params["layers"]) - 1 else agg
    return x


def loss_fn(params, batch, cfg: GCNConfig) -> jax.Array:
    logits = forward(params, batch, cfg)
    if cfg.task == "energy":
        n_graphs = batch["graph_targets"].shape[0]
        energy = jax.ops.segment_sum(logits[:, 0], batch["graph_id"], num_segments=n_graphs)
        err = energy - batch["graph_targets"]
        return jnp.mean(err * err)
    labels = batch["labels"]
    lg = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lg, labels[:, None], axis=1))
