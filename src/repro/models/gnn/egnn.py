"""EGNN (Satorras et al., arXiv:2102.09844): E(n)-equivariant GNN.

Messages are built from invariants (h_i, h_j, |x_i-x_j|^2); coordinates are
updated along relative-position directions, which keeps the layer exactly
E(n)-equivariant. Assigned config: 4 layers, hidden 64.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 16            # 0 -> species-embedding input
    n_out: int = 1              # per-graph scalar (energy) or per-node classes
    n_species: int = 16
    task: str = "energy"        # "energy" | "node_class"
    coord_update: bool = True
    param_dtype: object = jnp.float32


def init_params(rng, cfg: EGNNConfig) -> dict:
    d = cfg.d_hidden
    ks = jax.random.split(rng, cfg.n_layers * 3 + 2)
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "phi_e": common.mlp_init(ks[3 * i], [2 * d + 1, d, d], cfg.param_dtype),
                "phi_x": common.mlp_init(ks[3 * i + 1], [d, d, 1], cfg.param_dtype),
                "phi_h": common.mlp_init(ks[3 * i + 2], [2 * d, d, d], cfg.param_dtype),
            }
        )
    if cfg.d_feat > 0:
        enc = common.mlp_init(ks[-2], [cfg.d_feat, d], cfg.param_dtype)
    else:
        enc = (jax.random.normal(ks[-2], (cfg.n_species, d)) * 0.5).astype(cfg.param_dtype)
    return {
        "encoder": enc,
        "layers": layers,
        "readout": common.mlp_init(ks[-1], [d, d, cfg.n_out], cfg.param_dtype),
    }


def forward(params, batch, cfg: EGNNConfig):
    """batch: node_feat (n,F) or species (n,); pos (n,3); edge_index (2,E)."""
    src, dst = batch["edge_index"]
    n = batch["pos"].shape[0]
    if cfg.d_feat > 0:
        h = common.mlp_apply(params["encoder"], batch["node_feat"], final_act=True)
    else:
        h = params["encoder"][batch["species"]]
    x = batch["pos"].astype(h.dtype)
    for lp in params["layers"]:
        rel = x[dst] - x[src]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = common.mlp_apply(
            lp["phi_e"], jnp.concatenate([h[src], h[dst], d2], axis=-1), final_act=True
        )
        if cfg.coord_update:
            scale = common.mlp_apply(lp["phi_x"], m)
            upd = rel / (jnp.sqrt(d2) + 1.0) * scale
            x = x + common.scatter_mean(upd, dst, n)
        agg = common.scatter_sum(m, dst, n)
        h = h + common.mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
    node_out = common.mlp_apply(params["readout"], h)
    return node_out, x


def loss_fn(params, batch, cfg: EGNNConfig) -> jax.Array:
    node_out, _ = forward(params, batch, cfg)
    if cfg.task == "energy":
        n_graphs = batch["graph_targets"].shape[0]
        energy = jax.ops.segment_sum(node_out[:, 0], batch["graph_id"], num_segments=n_graphs)
        err = energy - batch["graph_targets"]
        return jnp.mean(err * err)
    lg = jax.nn.log_softmax(node_out.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lg, batch["labels"][:, None], axis=1))
