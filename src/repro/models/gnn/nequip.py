"""NequIP (Batzner et al., arXiv:2101.03164): O(3)-equivariant interatomic
potential via irreps tensor-product message passing.

Assigned config: 5 layers, 32 channels, l_max=2, 8 Bessel RBFs, cutoff 5.
Messages: per edge, CG tensor product of source features with the edge's
spherical harmonics, weighted per (path, channel) by a radial MLP, aggregated
by segment_sum — the O(L^6) full product is truncated at l_max (eSCN-style
path pruning is the kernel-regime note in the taxonomy; at l_max=2 the path
set is the full 15).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common, irreps


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    d_feat: int = 0          # >0: dense node features instead of species
    n_out: int = 1
    task: str = "energy"     # "energy" | "node_class"
    param_dtype: object = jnp.float32


def _paths(cfg) -> list[tuple[int, int, int]]:
    return irreps.cg_paths(cfg.l_max)


def init_params(rng, cfg: NequIPConfig) -> dict:
    c = cfg.d_hidden
    paths = _paths(cfg)
    n_keys = cfg.n_layers * 4 + 3
    ks = jax.random.split(rng, n_keys)
    layers = []
    for i in range(cfg.n_layers):
        k0, k1, k2, k3 = ks[4 * i : 4 * i + 4]
        layers.append(
            {
                "radial": common.mlp_init(k0, [cfg.n_rbf, 32, len(paths) * c], cfg.param_dtype),
                "lin_msg": {
                    str(l): (jax.random.normal(jax.random.fold_in(k1, l), (c, c)) / c**0.5).astype(cfg.param_dtype)
                    for l in range(cfg.l_max + 1)
                },
                "lin_self": {
                    str(l): (jax.random.normal(jax.random.fold_in(k2, l), (c, c)) / c**0.5).astype(cfg.param_dtype)
                    for l in range(cfg.l_max + 1)
                },
            }
        )
    if cfg.d_feat > 0:
        enc = common.mlp_init(ks[-3], [cfg.d_feat, c], cfg.param_dtype)
    else:
        enc = (jax.random.normal(ks[-3], (cfg.n_species, c)) * 0.5).astype(cfg.param_dtype)
    return {
        "encoder": enc,
        "layers": layers,
        "readout": common.mlp_init(ks[-1], [c, c, cfg.n_out], cfg.param_dtype),
    }


def _embed(params, batch, cfg):
    if cfg.d_feat > 0:
        s = common.mlp_apply(params["encoder"], batch["node_feat"], final_act=True)
    else:
        s = params["encoder"][batch["species"]]
    n = s.shape[0]
    feats = {0: s[:, :, None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, cfg.d_hidden, 2 * l + 1), s.dtype)
    return feats


def forward(params, batch, cfg: NequIPConfig):
    src, dst = batch["edge_index"]
    pos = batch["pos"]
    n = pos.shape[0]
    c = cfg.d_hidden
    rel = pos[dst] - pos[src]
    r = jnp.linalg.norm(rel, axis=-1)
    rbf = irreps.bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    ylm = irreps.sh(rel, cfg.l_max)
    paths = _paths(cfg)
    feats = _embed(params, batch, cfg)
    for lp in params["layers"]:
        radial = common.mlp_apply(lp["radial"], rbf)  # (E, P*c)
        radial = radial.reshape(radial.shape[0], len(paths), c)
        src_feats = {l: x[src] for l, x in feats.items()}
        path_w = {p: radial[:, i, :] for i, p in enumerate(paths)}
        msgs = irreps.tensor_product(src_feats, ylm, path_w, cfg.l_max)
        agg = {l: common.scatter_sum(m.reshape(m.shape[0], -1), dst, n).reshape(n, c, 2 * l + 1)
               for l, m in msgs.items()}
        mixed = irreps.linear_mix(agg, {int(l): w for l, w in lp["lin_msg"].items()})
        selfc = irreps.linear_mix(feats, {int(l): w for l, w in lp["lin_self"].items()})
        new = {l: mixed.get(l, 0) + selfc.get(l, 0) for l in feats}
        feats = irreps.gate(new)
    node_scalar = feats[0][:, :, 0]
    return common.mlp_apply(params["readout"], node_scalar)


def loss_fn(params, batch, cfg: NequIPConfig) -> jax.Array:
    out = forward(params, batch, cfg)
    if cfg.task == "energy":
        n_graphs = batch["graph_targets"].shape[0]
        energy = jax.ops.segment_sum(out[:, 0], batch["graph_id"], num_segments=n_graphs)
        err = energy - batch["graph_targets"]
        return jnp.mean(err * err)
    lg = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lg, batch["labels"][:, None], axis=1))
