"""Shared GNN substrate: edge-index message passing via segment reductions.

JAX sparse is BCOO-only, so message passing is implemented the TPU-native
way: gather source-node features by edge index, transform, and scatter-add
into destination nodes with jax.ops.segment_sum. Under the distributed
runtime the edge arrays are sharded across devices and the segment_sum
becomes partial-scatter + all-reduce (see train/gnn_step.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_sum(messages: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_sum(messages, dst, num_segments=n_nodes)


def scatter_mean(messages: jax.Array, dst: jax.Array, n_nodes: int, eps: float = 1e-9):
    s = scatter_sum(messages, dst, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0],), messages.dtype), dst, num_segments=n_nodes)
    return s / jnp.maximum(cnt, eps)[:, None]


def degree(dst: jax.Array, n_nodes: int, dtype=jnp.float32) -> jax.Array:
    return jax.ops.segment_sum(jnp.ones(dst.shape, dtype), dst, num_segments=n_nodes)


def mlp_init(rng, dims: list[int], dtype=jnp.float32):
    ks = jax.random.split(rng, len(dims) - 1)
    return [
        {
            "w": (jax.random.normal(k, (a, b)) / a**0.5).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def mlp_apply(layers, x, act=jax.nn.silu, final_act: bool = False):
    for i, l in enumerate(layers):
        x = x @ l["w"].astype(x.dtype) + l["b"].astype(x.dtype)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x
