"""xDeepFM (Lian et al., arXiv:1803.05170): CIN + DNN + linear over sparse
feature embeddings.

Assigned config: 39 sparse fields, embed_dim 10, CIN 200-200-200, MLP 400-400.
JAX has no native EmbeddingBag: lookups are jnp.take over row-sharded tables
and multi-hot bags reduce with jax.ops.segment_sum — implemented here as a
first-class module. The `retrieval_cand` shape scores one query against 10^6
candidates with a batched dot and the paper-style streaming top-k kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.models.gnn.common import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str
    n_sparse: int = 39
    embed_dim: int = 10
    table_rows: int = 100_000       # rows per field table
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_layers: tuple[int, ...] = (400, 400)
    multi_hot_fields: int = 4       # first fields take bags, rest single-hot
    bag_size: int = 3
    param_dtype: object = jnp.float32


# ---------------------------------------------------------------------------
# EmbeddingBag: take + segment_sum (multi-hot) over row-sharded tables
# ---------------------------------------------------------------------------

def embedding_bag(table: jax.Array, indices: jax.Array, offsets_or_none=None, mode="sum"):
    """table (R, D); indices (B, bag) int32 (-1 = pad) -> (B, D)."""
    emb = jnp.take(table, jnp.maximum(indices, 0), axis=0)
    mask = (indices >= 0).astype(emb.dtype)[..., None]
    summed = jnp.sum(emb * mask, axis=-2)
    if mode == "mean":
        summed = summed / jnp.maximum(mask.sum(-2), 1.0)
    return summed


def embedding_bag_ragged(table: jax.Array, flat_indices: jax.Array, bag_ids: jax.Array, n_bags: int):
    """Ragged form: flat (N,) indices with bag ids -> segment_sum reduce."""
    emb = jnp.take(table, flat_indices, axis=0)
    return jax.ops.segment_sum(emb, bag_ids, num_segments=n_bags)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_params(rng, cfg: XDeepFMConfig) -> dict:
    ks = jax.random.split(rng, 6)
    f, d = cfg.n_sparse, cfg.embed_dim
    tables = (jax.random.normal(ks[0], (f, cfg.table_rows, d)) * 0.01).astype(cfg.param_dtype)
    lin_tables = (jax.random.normal(ks[1], (f, cfg.table_rows)) * 0.01).astype(cfg.param_dtype)
    cin = []
    h_prev = f
    for i, h in enumerate(cfg.cin_layers):
        w = jax.random.normal(jax.random.fold_in(ks[2], i), (h, h_prev, f)) / (h_prev * f) ** 0.5
        cin.append(w.astype(cfg.param_dtype))
        h_prev = h
    mlp = mlp_init(ks[3], [f * d, *cfg.mlp_layers, 1], cfg.param_dtype)
    out_cin = (
        jax.random.normal(ks[4], (sum(cfg.cin_layers), 1)) / sum(cfg.cin_layers) ** 0.5
    ).astype(cfg.param_dtype)
    return {"tables": tables, "lin_tables": lin_tables, "cin": cin, "mlp": mlp,
            "out_cin": out_cin, "bias": jnp.zeros((), cfg.param_dtype)}


def param_specs(cfg: XDeepFMConfig, rules) -> dict:
    tp = rules.ax(rules.tp, cfg.table_rows)
    dims = [cfg.n_sparse * cfg.embed_dim, *cfg.mlp_layers, 1]
    mlp_specs = [
        {"w": P(rules.ax(rules.fsdp, a), None), "b": P(None)}
        for a in dims[:-1]
    ]
    return {
        "tables": P(None, tp, None),      # row-sharded embedding tables
        "lin_tables": P(None, tp),
        "cin": [P(None, None, None) for _ in cfg.cin_layers],
        "mlp": mlp_specs,
        "out_cin": P(None, None),
        "bias": P(),
    }


def _embed_fields(params, batch, cfg: XDeepFMConfig):
    """batch['sparse_ids'] (B, F, bag) int32, -1 padded -> (B, F, D)."""
    ids = batch["sparse_ids"]

    def field(table, idx):
        return embedding_bag(table, idx)

    emb = jax.vmap(field, in_axes=(0, 1), out_axes=1)(params["tables"], ids)  # (B,F,D)
    lin = jax.vmap(
        lambda t, i: jnp.sum(jnp.take(t, jnp.maximum(i, 0)) * (i >= 0), axis=-1),
        in_axes=(0, 1),
        out_axes=1,
    )(params["lin_tables"], ids)  # (B,F)
    return emb, lin


def _cin(params, x0: jax.Array, cfg: XDeepFMConfig) -> jax.Array:
    """Compressed Interaction Network. x0 (B, F, D) -> (B, sum(H))."""
    xk = x0
    outs = []
    for w in params["cin"]:
        z = jnp.einsum("bid,bjd->bijd", xk, x0)
        xk = jnp.einsum("bijd,hij->bhd", z, w.astype(z.dtype))
        outs.append(jnp.sum(xk, axis=-1))  # (B, H)
    return jnp.concatenate(outs, axis=-1)


def forward(params, batch, cfg: XDeepFMConfig) -> jax.Array:
    emb, lin = _embed_fields(params, batch, cfg)
    b = emb.shape[0]
    cin_feat = _cin(params, emb, cfg)
    dnn = mlp_apply(params["mlp"], emb.reshape(b, -1))
    logit = (
        dnn[:, 0]
        + (cin_feat @ params["out_cin"].astype(cin_feat.dtype))[:, 0]
        + lin.sum(-1)
        + params["bias"].astype(emb.dtype)
    )
    return logit


def loss_fn(params, batch, cfg: XDeepFMConfig) -> jax.Array:
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def retrieval_score(params, batch, cfg: XDeepFMConfig, k: int = 100, *, use_pallas: bool = True):
    """`retrieval_cand`: one query vs n_candidates items, exact top-k.

    Query embedding = sum of the query's field embeddings; candidates live in
    field 0's table (the item table). Scoring = batched dot; selection = the
    streaming retrieval_topk kernel (the same top-k primitive as KNN-Index).
    """
    emb, _ = _embed_fields(params, batch, cfg)  # (1, F, D)
    q = emb.sum(axis=1)  # (1, D)
    cand = params["tables"][0, : batch["n_candidates"]]  # (N, D)
    scores = (q @ cand.T.astype(q.dtype))  # (1, N)
    return kops.retrieval_topk(scores, k, use_pallas=use_pallas)
