"""Minimal functional NN substrate (no flax in the container).

Params are nested dicts of jax.Arrays; every init_* has a matching spec_*
returning a PartitionSpec tree of the same structure. Axis names used in the
specs are LOGICAL ("batch", "model", "expert", ...) and are resolved to mesh
axes by repro.distributed.sharding.resolve_specs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dense_init(rng, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32, scale: float | None = None):
    std = (scale if scale is not None else 1.0) / (d_in ** 0.5)
    p = {"w": (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def dense_spec(in_axis, out_axis, *, bias: bool = False):
    s = {"w": P(in_axis, out_axis)}
    if bias:
        s["b"] = P(out_axis)
    return s


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_spec():
    return {"g": P(None)}


def rope_freqs(d_head: int, max_pos: int, theta: float = 10000.0) -> jax.Array:
    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    return jnp.outer(t, inv)  # (max_pos, d_head//2)


def apply_rope(x: jax.Array, pos: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); pos: (S,) or broadcastable int positions."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos.astype(jnp.float32)[..., None] * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def chunked_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
    probs_dtype=None,  # store attention probabilities in this dtype (e.g.
                       # bf16) — halves the dominant HBM traffic of the block
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (lax.scan blocked).

    Never materialises the (S, T) score matrix: peak intermediate is
    (B, H, q_chunk, kv_chunk). GQA is handled with a grouped-head einsum —
    KV is NEVER repeated/materialised per query head, which both avoids the
    rep-times K/V traffic and (with a sharded KV cache) the SPMD all-gather a
    broadcast repeat would force (EXPERIMENTS.md §Perf cell D).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    scale = d ** -0.5

    def _divisor(total: int, want: int) -> int:
        c = min(want, total)
        while total % c:
            c -= 1
        return c

    qc = _divisor(s, q_chunk)
    kc = _divisor(t, kv_chunk)
    nq, nk = s // qc, t // kc

    # q: (nq, b, hkv, rep, qc, d); kv: (nk, b, hkv, kc, d)
    qb = (
        q.reshape(b, s, hkv, rep, d).transpose(1, 0, 2, 3, 4)
        .reshape(nq, qc, b, hkv, rep, d).transpose(0, 2, 3, 4, 1, 5)
    )
    kb = k.transpose(1, 0, 2, 3).reshape(nk, kc, b, hkv, d).transpose(0, 2, 3, 1, 4)
    vb = v.transpose(1, 0, 2, 3).reshape(nk, kc, b, hkv, d).transpose(0, 2, 3, 1, 4)

    def q_step(_, qi):
        q_blk, qidx = qi  # (b, hkv, rep, qc, d)
        q_pos = q_offset + qidx * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            acc, m, l = carry
            k_blk, v_blk, kidx = ki  # (b, hkv, kc, d)
            sc = jnp.einsum("bgrqd,bgkd->bgrqk", q_blk, k_blk).astype(jnp.float32) * scale
            if causal:
                k_pos = kidx * kc + jnp.arange(kc)
                mask = q_pos[:, None] >= k_pos[None, :]
                sc = jnp.where(mask, sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            if probs_dtype is not None:
                p = p.astype(probs_dtype)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.astype(jnp.float32).sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((b, hkv, rep, qc, d), jnp.float32),
            jnp.full((b, hkv, rep, qc), -jnp.inf, jnp.float32),
            jnp.zeros((b, hkv, rep, qc), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            kv_step, init, (kb, vb, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    # out: (nq, b, hkv, rep, qc, d) -> (b, s, h, d)
    return (
        out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, d)
    )


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE; stable in fp32. logits (..., V), labels (...) int."""
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
