"""Decoder-only transformer family: dense + MoE, GQA, QKV-bias, RoPE, KV cache.

Covers the five assigned LM architectures (granite-moe-1b-a400m,
llama4-scout-17b-a16e, qwen2.5-3b, internlm2-20b, qwen1.5-110b). Layers are
scan-stacked (params carry a leading L dim) so the 80-layer 110B config lowers
to a compact HLO; each layer is rematerialised (jax.checkpoint) in training.

MoE uses sort-based token routing (argsort by expert, capacity-bounded groups,
scatter-add combine) — the dispatch never materialises the (tokens, E, C)
one-hot tensor, and expert weights shard over the "ep" (= mesh model) axis.
The router's top-k is the same top-k-selection primitive family as the
paper's KNN merge kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import nn


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    moe_top_k: int = 1
    capacity_factor: float = 1.25
    rope_theta: float = 10000.0
    param_dtype: Any = jnp.bfloat16
    q_chunk: int = 512
    kv_chunk: int = 1024
    # §Perf knobs (see EXPERIMENTS.md): bf16 attention probabilities and the
    # activation-checkpoint policy for the layer scan
    attn_probs_bf16: bool = False
    remat_policy: str = "full"  # "full" (recompute all) | "dots" (save matmuls)
    moe_ep_constraint: bool = False  # force expert-sharded dispatch buffers
                                     # (refuted under GSPMD; §Perf cell E)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, hd = self.d_model, self.d_head
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        emb = 2 * self.vocab * d
        return self.n_layers * (attn + ffn) + emb

    def active_param_count(self) -> int:
        d, hd = self.d_model, self.d_head
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * self.d_ff * (self.moe_top_k if self.is_moe else 1)
        emb = 2 * self.vocab * d
        return self.n_layers * (attn + ffn) + emb


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: TransformerConfig) -> dict:
    dt = cfg.param_dtype
    d, hd = cfg.d_model, cfg.d_head
    keys = jax.random.split(rng, 8)

    def layer_init(k):
        ks = jax.random.split(k, 10)
        p = {
            "ln1": nn.rmsnorm_init(d, dt),
            "wq": nn.dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dt),
            "wk": nn.dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
            "wv": nn.dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
            "wo": nn.dense_init(ks[3], cfg.n_heads * hd, d, dtype=dt),
            "ln2": nn.rmsnorm_init(d, dt),
        }
        if cfg.is_moe:
            e, f = cfg.n_experts, cfg.d_ff
            std = 1.0 / math.sqrt(d)
            p["router"] = {"w": jax.random.normal(ks[4], (d, e), jnp.float32) * std}
            p["w_gate"] = (jax.random.normal(ks[5], (e, d, f)) * std).astype(dt)
            p["w_up"] = (jax.random.normal(ks[6], (e, d, f)) * std).astype(dt)
            p["w_down"] = (jax.random.normal(ks[7], (e, f, d)) * (1.0 / math.sqrt(f))).astype(dt)
        else:
            p["w_gate"] = nn.dense_init(ks[5], d, cfg.d_ff, dtype=dt)
            p["w_up"] = nn.dense_init(ks[6], d, cfg.d_ff, dtype=dt)
            p["w_down"] = nn.dense_init(ks[7], cfg.d_ff, d, dtype=dt)
        return p

    layers = jax.vmap(layer_init)(jax.random.split(keys[0], cfg.n_layers))
    emb_std = 1.0 / math.sqrt(d)
    return {
        "embed": (jax.random.normal(keys[1], (cfg.vocab, d)) * emb_std).astype(dt),
        "layers": layers,
        "ln_f": nn.rmsnorm_init(d, dt),
        "unembed": (jax.random.normal(keys[2], (d, cfg.vocab)) * emb_std).astype(dt),
    }


def param_specs(cfg: TransformerConfig, rules) -> dict:
    """PartitionSpec tree matching init_params. `rules` is a ShardingRules."""
    d, hd = cfg.d_model, cfg.d_head
    fsdp, tp = rules.ax(rules.fsdp, d), rules.tp
    heads_tp = tp if (tp and cfg.n_heads % rules.tp_size == 0) else None
    kv_tp = tp if (tp and cfg.n_kv_heads % rules.tp_size == 0) else None
    vocab_tp = rules.ax(tp, cfg.vocab)
    L = None  # stacked layer dim is never sharded

    def dense_s(a, b, bias):
        s = {"w": P(L, a, b)}
        if bias:
            s["b"] = P(L, b)
        return s

    layer = {
        "ln1": {"g": P(L, None)},
        "wq": dense_s(fsdp, heads_tp, cfg.qkv_bias),
        "wk": dense_s(fsdp, kv_tp, cfg.qkv_bias),
        "wv": dense_s(fsdp, kv_tp, cfg.qkv_bias),
        "wo": {"w": P(L, heads_tp, fsdp)},
        "ln2": {"g": P(L, None)},
    }
    if cfg.is_moe:
        ep_ok = rules.tp and cfg.n_experts % rules.tp_size == 0
        ep = rules.tp if ep_ok else None
        layer["router"] = {"w": P(L, fsdp, None)}
        layer["w_gate"] = P(L, ep, fsdp, None)
        layer["w_up"] = P(L, ep, fsdp, None)
        layer["w_down"] = P(L, ep, None, fsdp)
    else:
        ff_tp = rules.ax(tp, cfg.d_ff)
        layer["w_gate"] = {"w": P(L, fsdp, ff_tp)}
        layer["w_up"] = {"w": P(L, fsdp, ff_tp)}
        layer["w_down"] = {"w": P(L, ff_tp, fsdp)}
    return {
        "embed": P(vocab_tp, fsdp),
        "layers": layer,
        "ln_f": {"g": P(None)},
        "unembed": P(fsdp, vocab_tp),
    }


# ---------------------------------------------------------------------------
# MoE FFN: sort-based capacity routing
# ---------------------------------------------------------------------------

def _moe_ffn(lp, x2d: jax.Array, cfg: TransformerConfig, rules=None) -> jax.Array:
    n_tok, d = x2d.shape
    e, kk = cfg.n_experts, cfg.moe_top_k
    cap = int(math.ceil(n_tok * kk / e * cfg.capacity_factor))
    logits = x2d.astype(jnp.float32) @ lp["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, kk)  # (N, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kk)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(se.shape[0], dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, se.astype(jnp.int32) * cap + pos, e * cap)  # pad slot

    # Optionally force expert-sharded dispatch buffers. Measured (§Perf cell
    # E): GSPMD's own token-sharded strategy is ~2x cheaper — forcing EP here
    # inserts resharding both ways — so this stays opt-in/off.
    use_ep = (
        cfg.moe_ep_constraint
        and rules is not None
        and rules.tp
        and e % rules.tp_size == 0
    )
    grouped = jnp.zeros((e * cap + 1, d), x2d.dtype).at[dest].set(x2d[st])
    grouped = grouped[:-1].reshape(e, cap, d)
    if use_ep:
        grouped = rules.constrain(grouped, P(rules.tp, None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", grouped, lp["w_gate"].astype(x2d.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", grouped, lp["w_up"].astype(x2d.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, lp["w_down"].astype(x2d.dtype))
    if use_ep:
        y = rules.constrain(y, P(rules.tp, None, None))
    y_flat = jnp.concatenate([y.reshape(e * cap, d), jnp.zeros((1, d), y.dtype)])
    contrib = y_flat[dest] * (sg * keep).astype(y.dtype)[:, None]
    return jnp.zeros((n_tok, d), x2d.dtype).at[st].add(contrib)


def _dense_ffn(lp, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(nn.dense_apply(lp["w_gate"], x)) * nn.dense_apply(lp["w_up"], x)
    return nn.dense_apply(lp["w_down"], h)


# ---------------------------------------------------------------------------
# forward / prefill / decode
# ---------------------------------------------------------------------------

def _attn_proj(lp, x, cfg, pos):
    b, s, d = x.shape
    hd = cfg.d_head
    q = nn.dense_apply(lp["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = nn.dense_apply(lp["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = nn.dense_apply(lp["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q = nn.apply_rope(q, pos, cfg.rope_theta)
    k = nn.apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def _layer_fwd(lp, x, cfg: TransformerConfig, rules=None):
    b, s, d = x.shape
    pos = jnp.arange(s)
    q, k, v = _attn_proj(lp, nn.rmsnorm_apply(lp["ln1"], x), cfg, pos)
    if rules is not None:
        q = rules.constrain(q, P(rules.batch, None, rules.heads_axis(cfg.n_heads), None))
    o = nn.chunked_attention(
        q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        probs_dtype=jnp.bfloat16 if cfg.attn_probs_bf16 else None,
    )
    x = x + nn.dense_apply(lp["wo"], o.reshape(b, s, cfg.n_heads * cfg.d_head))
    h = nn.rmsnorm_apply(lp["ln2"], x)
    if cfg.is_moe:
        y = _moe_ffn(lp, h.reshape(b * s, d), cfg, rules).reshape(b, s, d)
    else:
        if rules is not None:
            h = rules.constrain(h, P(rules.batch, None, None))
        y = _dense_ffn(lp, h)
    return x + y


def forward(params: dict, tokens: jax.Array, cfg: TransformerConfig, rules=None) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V). Layers scanned + rematerialised."""
    x = params["embed"].astype(cfg.param_dtype)[tokens]
    if rules is not None:
        x = rules.constrain(x, P(rules.batch, None, None))

    def body(carry, lp):
        return _layer_fwd(lp, carry, cfg, rules), None

    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy)
    else:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = nn.rmsnorm_apply(params["ln_f"], x)
    logits = x @ params["unembed"].astype(x.dtype)
    if rules is not None:
        logits = rules.constrain(logits, P(rules.batch, None, rules.tp))
    return logits


def loss_fn(params, batch, cfg: TransformerConfig, rules=None) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg, rules)
    return nn.cross_entropy(logits, batch["labels"])


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or cfg.param_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: TransformerConfig, rules, layout: str = "auto",
                batch_size: int | None = None) -> dict:
    """KV-cache sharding. layout:
      auto : heads over tp when divisible, else sequence over tp
      d    : head_dim over tp (score psum instead of seq resharding — the
             §Perf decode variant for kv_heads < tp_size)
    batch_size (if given) drops the batch axes when they don't divide it
    (e.g. the long_500k single-request cell)."""
    bax = rules.batch if batch_size is None else rules.ax(rules.batch, batch_size)
    kv_tp = rules.tp if (rules.tp and cfg.n_kv_heads % rules.tp_size == 0) else None
    if layout == "d" and cfg.d_head % max(1, rules.tp_size) == 0:
        spec = P(None, bax, None, None, rules.tp)
    else:
        seq_ax = rules.tp if kv_tp is None else None  # shard seq when heads can't be
        spec = P(None, bax, seq_ax, kv_tp, None)
    return {"k": spec, "v": spec, "len": P()}


def prefill(params, tokens: jax.Array, cfg: TransformerConfig, max_len: int, rules=None):
    """Run the prompt through the model, returning (last_logits, cache)."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.param_dtype)[tokens]
    pos = jnp.arange(s)

    def body(x, lp):
        q, k, v = _attn_proj(lp, nn.rmsnorm_apply(lp["ln1"], x), cfg, pos)
        o = nn.chunked_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + nn.dense_apply(lp["wo"], o.reshape(b, s, cfg.n_heads * cfg.d_head))
        h = nn.rmsnorm_apply(lp["ln2"], x)
        if cfg.is_moe:
            y = _moe_ffn(lp, h.reshape(b * s, -1), cfg, rules).reshape(x.shape)
        else:
            y = _dense_ffn(lp, h)
        kc = jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.d_head), k.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        return x + y, (kc, vc)

    x, (kcache, vcache) = jax.lax.scan(body, x, params["layers"])
    x = nn.rmsnorm_apply(params["ln_f"], x[:, -1:])
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    cache = {"k": kcache, "v": vcache, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cache: dict, tokens: jax.Array, cfg: TransformerConfig, rules=None):
    """One autoregressive step. tokens (B,) -> logits (B, V), updated cache."""
    b = tokens.shape[0]
    t = cache["k"].shape[2]
    cur = cache["len"]
    x = params["embed"].astype(cfg.param_dtype)[tokens][:, None, :]  # (B,1,d)
    pos = cur[None]

    def body(x, inputs):
        lp, kc, vc = inputs
        q, k, v = _attn_proj(lp, nn.rmsnorm_apply(lp["ln1"], x), cfg, pos)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, cur, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, cur, 0, 0))
        # full-length masked attention against the cache. GQA via grouped
        # einsum — never repeat/materialise KV per query head (a broadcast
        # repeat forces SPMD to all-gather the sharded cache; §Perf cell D).
        rep = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, 1, cfg.n_kv_heads, rep, cfg.d_head)
        sc = jnp.einsum("bqgrd,btgd->bgrqt", qg, kc).astype(jnp.float32)
        sc = sc * cfg.d_head**-0.5
        mask = (jnp.arange(t) <= cur)[None, None, None, None, :]
        sc = jnp.where(mask, sc, -jnp.inf)
        w = jax.nn.softmax(sc, axis=-1).astype(vc.dtype)
        o = jnp.einsum("bgrqt,btgd->bqgrd", w, vc)
        x = x + nn.dense_apply(lp["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
        h = nn.rmsnorm_apply(lp["ln2"], x)
        if cfg.is_moe:
            y = _moe_ffn(lp, h.reshape(b, -1), cfg, rules).reshape(x.shape)
        else:
            y = _dense_ffn(lp, h)
        return x + y, (kc, vc)

    x, (kcache, vcache) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = nn.rmsnorm_apply(params["ln_f"], x)
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    new_cache = {"k": kcache, "v": vcache, "len": cur + 1}
    return logits, new_cache
