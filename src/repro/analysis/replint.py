"""replint — the static rail. ``python -m repro.analysis.replint src/``.

Stdlib-only by construction (no jax import anywhere on this path): the
blocking ``analyze`` CI job runs it on a bare interpreter before the test
environment is even built.

Suppression policy: a finding is silenced only by

    # replint: disable=REPxxx(reason why this is safe)

on the offending line, or on the ``def``/``class`` line of the enclosing
block (which silences that rule for the whole block — the cached-jit-factory
pattern). The reason string is **mandatory**: a bare ``disable=REP003``
is itself reported (REP000). Exit status is 1 iff any finding survives.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from pathlib import Path

from repro.analysis.callgraph import ModuleInfo, build_callgraph, module_name_for
from repro.analysis.rules import Context, Finding, all_rules

_PRAGMA_RE = re.compile(r"#\s*replint:\s*disable=(.+)$")
_CODE_WITH_REASON = re.compile(r"(REP\d{3})\s*\(([^)]*)\)")
_CODE_BARE = re.compile(r"(REP\d{3})(?!\s*\()")


def collect_files(paths: list[str]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(
                f for f in sorted(path.rglob("*.py")) if "__pycache__" not in f.parts
            )
        elif path.suffix == ".py":
            files.append(path)
    return files


def parse_modules(files: list[Path]) -> tuple[dict[str, ModuleInfo], list[Finding]]:
    modules: dict[str, ModuleInfo] = {}
    errors: list[Finding] = []
    for f in files:
        rel = f.as_posix()
        try:
            source = f.read_text()
            tree = ast.parse(source, filename=rel)
        except (SyntaxError, UnicodeDecodeError) as exc:
            line = getattr(exc, "lineno", 1) or 1
            errors.append(Finding(rel, line, 0, "REP000", f"parse error: {exc.msg if hasattr(exc, 'msg') else exc}"))
            continue
        modules[rel] = ModuleInfo(
            path=rel, module=module_name_for(rel), tree=tree, source=source
        )
    return modules, errors


class Suppressions:
    """Per-file map of (code -> suppressed line ranges) from pragmas."""

    def __init__(self, mod: ModuleInfo):
        self.ranges: dict[str, list[tuple[int, int]]] = {}
        self.bad_pragmas: list[Finding] = []
        blocks: dict[int, int] = {}  # def/class lineno -> end_lineno
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                blocks[node.lineno] = node.end_lineno or node.lineno
        for lineno, line in enumerate(mod.source.splitlines(), start=1):
            m = _PRAGMA_RE.search(line)
            if not m:
                continue
            spec = m.group(1)
            reasoned = _CODE_WITH_REASON.findall(spec)
            bare = _CODE_BARE.findall(_CODE_WITH_REASON.sub("", spec))
            for code in bare:
                self.bad_pragmas.append(
                    Finding(
                        mod.path, lineno, 0, "REP000",
                        f"pragma disables {code} without a reason — "
                        f"write `# replint: disable={code}(why this is safe)`",
                    )
                )
            for code, reason in reasoned:
                if not reason.strip():
                    self.bad_pragmas.append(
                        Finding(
                            mod.path, lineno, 0, "REP000",
                            f"pragma disables {code} with an empty reason",
                        )
                    )
                    continue
                end = blocks.get(lineno, lineno)
                self.ranges.setdefault(code, []).append((lineno, end))

    def covers(self, finding: Finding) -> bool:
        return any(
            lo <= finding.line <= hi for lo, hi in self.ranges.get(finding.code, [])
        )


def run(paths: list[str], select: set[str] | None = None) -> list[Finding]:
    modules, findings = parse_modules(collect_files(paths))
    graph = build_callgraph(modules)
    ctx = Context(modules=modules, graph=graph)
    suppressions = {path: Suppressions(mod) for path, mod in modules.items()}
    for sup in suppressions.values():
        findings.extend(sup.bad_pragmas)
    for rule in all_rules():
        if select and rule.code not in select:
            continue
        for f in rule.check(ctx):
            sup = suppressions.get(f.path)
            if sup is None or not sup.covers(f):
                findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.code))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="replint", description="device-residency invariant linter"
    )
    ap.add_argument("paths", nargs="*", default=["src/"], help="files or directories")
    ap.add_argument("--select", help="comma-separated rule codes (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.code}  {rule.summary}")
        return 0

    select = set(args.select.split(",")) if args.select else None
    findings = run(args.paths or ["src/"], select)
    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"replint: {n} finding{'s' if n != 1 else ''}" if n else "replint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
