"""Correctness tooling for the repo's device-residency invariants.

Two rails:

* **Static** — ``repro.analysis.replint`` (stdlib-only, importable without
  jax): an AST rule engine over the source tree that mechanizes the
  invariants six PRs of performance work rely on. Run it as

      python -m repro.analysis.replint src/

  Rules (see ``repro.analysis.rules``): REP001 host materialization inside
  jit-reachable code, REP002 Pallas input/output-aliasing hazards, REP003
  recompile risks, REP004 the int32/float32 kernel-boundary dtype contract,
  REP005 module-level ``jnp`` computation. Violations are suppressed only by
  a justified pragma: ``# replint: disable=REPxxx(reason)`` — the reason
  string is mandatory and its absence is itself an error.

* **Runtime** — ``repro.analysis.sanitize`` (imports jax): transfer-guard
  context managers the engines run their query/flush paths under in
  sanitizer mode (``REPRO_SANITIZE=1``), a compile counter checked against
  ``tools/compile_budgets.json``, a post-flush table invariant scanner, and
  an aliasing sanitizer that replays each Pallas kernel on poisoned
  pad/dummy slots against its ``kernels/ref.py`` oracle.

``sanitize`` is deliberately NOT imported here: the static rail must stay
importable in a bare-stdlib environment (the blocking ``analyze`` CI job
runs it without installing the jax stack).
"""
