"""Runtime sanitizer rail: transfer guards, compile budgets, table scans.

The static rail (``replint``) proves properties of the *source*; this
module checks the ones only an execution can see:

* ``no_transfers()`` / ``guard(tag)`` — ``jax.transfer_guard("disallow")``
  around the engine's query/flush paths. Under the guard, an *implicit*
  transfer (a numpy array falling into a jitted call, an eager ``jnp.full``
  materializing a Python scalar, ``int()`` on a device scalar) raises;
  explicit ``jax.device_put`` / ``np.asarray(device_array)`` stay legal —
  exactly the discipline the serving paths are written to. The engines
  enable the guard when ``REPRO_SANITIZE=1`` (the sanitizer CI leg).
* ``count_compiles()`` — counts XLA backend compiles via the jax
  monitoring events, checked against ``tools/compile_budgets.json``
  (``assert_compiles_within``): a warm serving path that compiles is a
  regression of the 28->2 win, and it fails the test, not a log line.
* ``count_transfers()`` — counts explicit h2d (``jax.device_put``) and d2h
  (``__array__`` readbacks) so benchmarks can publish ``host_transfers``
  per row.
* ``scan_tables()`` — post-flush invariant scan of the (n, k) tables:
  NaN / negative / unsorted distances, out-of-range ids, pad slots that
  carry finite distances.
* ``check_kernel_aliasing()`` — replays the aliased Pallas kernels
  (``sweep_merge``, ``frontier_relax``) against their ``kernels/ref.py``
  oracles with *poisoned* buffers: every slot the kernel must mask or
  must not read through the donated operand (pad neighbor slots, the
  dummy row, donated-table garbage) is filled with trap values first.
  A kernel that reads through its aliased operand after the scatter, or
  forgets a pad mask, diverges from the oracle here.

Everything raises ``repro.core.errors.SanitizerError`` on violation.
"""
from __future__ import annotations

import contextlib
import json
import os
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
import jax._src.monitoring as _monitoring

from repro.core.errors import SanitizerError

_COMPILE_EVENT = "backend_compile"
_CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"


def enabled() -> bool:
    """Sanitizer mode: set ``REPRO_SANITIZE=1`` (the sanitizer CI leg)."""
    return os.environ.get("REPRO_SANITIZE", "").lower() in ("1", "true", "yes", "on")


@contextlib.contextmanager
def no_transfers(tag: str = ""):
    """Disallow implicit host<->device transfers inside the block."""
    try:
        with jax.transfer_guard("disallow"):
            yield
    except jax.errors.JaxRuntimeError as e:
        if "transfer" in str(e).lower():
            where = f" on the `{tag}` path" if tag else ""
            raise SanitizerError(
                f"implicit host transfer{where}: {e}\n"
                "Use jax.device_put for uploads and np.asarray(device_array) "
                "for explicit readbacks; never pass raw numpy into a jitted call."
            ) from e
        raise


def guard(tag: str = ""):
    """``no_transfers(tag)`` when sanitizer mode is on, else a no-op."""
    return no_transfers(tag) if enabled() else contextlib.nullcontext()


# ---------------------------------------------------------------------------
# compile counting + budgets
# ---------------------------------------------------------------------------


class CompileCounter:
    """Number of XLA backend compiles observed while the context was live.

    ``count`` is every backend compile — including ones served from the
    persistent compilation cache (jax still emits the backend_compile
    duration event on a cache hit, it is just ~ms instead of ~s).
    ``cache_hits`` counts the hits, so ``uncached`` (= count - cache_hits)
    is what a process actually paid to compile from scratch — the number
    the cold-boot budget pins.
    """

    def __init__(self):
        self.count = 0
        self.cache_hits = 0

    @property
    def uncached(self) -> int:
        return self.count - self.cache_hits

    def _listen(self, name: str, duration: float, **kw) -> None:
        if _COMPILE_EVENT in name:
            self.count += 1

    def _listen_event(self, name: str, **kw) -> None:
        if name == _CACHE_HIT_EVENT:
            self.cache_hits += 1


@contextlib.contextmanager
def count_compiles():
    counter = CompileCounter()
    _monitoring.register_event_duration_secs_listener(counter._listen)
    _monitoring.register_event_listener(counter._listen_event)
    try:
        yield counter
    finally:
        _monitoring._unregister_event_duration_listener_by_callback(counter._listen)
        _monitoring._unregister_event_listener_by_callback(counter._listen_event)


def enable_compile_cache(path: str | os.PathLike | None = None) -> Path | None:
    """Turn on jax's persistent compilation cache at ``path``.

    ``path`` defaults to the ``REPRO_COMPILE_CACHE`` env var; returns the
    cache directory (created if missing), or None when neither is set (the
    call is then a no-op, so serve.py can wire it unconditionally). The
    min-compile-time/min-entry-size floors are zeroed so even the CPU
    backend's fast compiles persist — the point is cold-boot serving, and
    a second boot should pay the *warm* budget, not the 28->2 win again.
    """
    path = path or os.environ.get("REPRO_COMPILE_CACHE") or None
    if not path:
        return None
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return path


def budgets_path() -> Path:
    env = os.environ.get("REPRO_COMPILE_BUDGETS")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "tools" / "compile_budgets.json"


def load_budgets() -> dict:
    with open(budgets_path()) as f:
        return json.load(f)


def assert_compiles_within(api: str, cold: int | None = None, warm: int | None = None):
    """Check measured compile counts against the checked-in budget.

    ``warm`` must EQUAL the budget (a warm path that compiles at all is a
    regression; a budget that is too loose is stale and must be lowered).
    ``cold`` must not exceed ``cold_max``.
    """
    budget = load_budgets().get(api)
    if budget is None:
        raise SanitizerError(
            f"no compile budget for `{api}` in {budgets_path()}; add one"
        )
    if cold is not None and cold > budget["cold_max"]:
        raise SanitizerError(
            f"`{api}` cold path compiled {cold} programs, budget cold_max="
            f"{budget['cold_max']} ({budgets_path()})"
        )
    if warm is not None and warm != budget["warm"]:
        raise SanitizerError(
            f"`{api}` warm path compiled {warm} programs, budget requires "
            f"exactly {budget['warm']} ({budgets_path()}); a higher count is a "
            "recompile regression, a lower budget means the file is stale"
        )


# ---------------------------------------------------------------------------
# transfer counting (benchmark `host_transfers` column)
# ---------------------------------------------------------------------------


class TransferCounter:
    def __init__(self):
        self.h2d = 0
        self.d2h = 0

    @property
    def total(self) -> int:
        return self.h2d + self.d2h


@contextlib.contextmanager
def count_transfers():
    """Count explicit host<->device crossings inside the block.

    h2d: ``jax.device_put`` calls (after the residency fixes, ALL serving
    uploads are explicit). d2h: ``np.asarray`` / ``np.array`` calls whose
    argument is a jax array — the repo's one idiom for explicit readback
    (numpy reaches the device buffer through the buffer protocol, so the
    interposition has to happen on the numpy side). Meant to run together
    with ``no_transfers``, which rules the implicit ones out.
    """
    counter = TransferCounter()
    orig_put = jax.device_put
    orig_asarray = np.asarray
    orig_array = np.array

    def counting_put(*args, **kwargs):
        counter.h2d += 1
        return orig_put(*args, **kwargs)

    def counting_asarray(a, *args, **kwargs):
        if isinstance(a, jax.Array):
            counter.d2h += 1
        return orig_asarray(a, *args, **kwargs)

    def counting_array(a, *args, **kwargs):
        if isinstance(a, jax.Array):
            counter.d2h += 1
        return orig_array(a, *args, **kwargs)

    jax.device_put = counting_put
    np.asarray = counting_asarray
    np.array = counting_array
    try:
        yield counter
    finally:
        jax.device_put = orig_put
        np.asarray = orig_asarray
        np.array = orig_array


# ---------------------------------------------------------------------------
# post-flush table scan
# ---------------------------------------------------------------------------


def scan_tables(ids, dists, n: int, *, context: str = "") -> None:
    """Invariant scan of host-layout (rows, k) tables; raises on corruption.

    Checked: ids int-typed in [-1, n); no NaN; no negative distance; rows
    ascending (ties allowed); pad slots (id == -1) at +inf and packed to
    the right of every real entry.
    """
    ids = np.asarray(ids)
    d = np.asarray(dists)
    where = f" ({context})" if context else ""
    problems = []
    if np.isnan(d).any():
        problems.append(f"{int(np.isnan(d).sum())} NaN distances")
    if (d < 0).any():
        problems.append(f"{int((d < 0).sum())} negative distances")
    if ids.size:
        if int(ids.min()) < -1 or int(ids.max()) >= n:
            problems.append(
                f"ids outside [-1, {n}): min={int(ids.min())} max={int(ids.max())}"
            )
        pad = ids < 0
        if not np.isinf(np.where(pad, d, np.inf)).all():
            problems.append("pad slots (id=-1) carrying finite distances")
        # pads packed right: a real id after a pad breaks the k-list contract
        if (np.diff(pad.astype(np.int8), axis=1) < 0).any():
            problems.append("real entries to the right of pad slots")
        dd = np.where(pad, np.inf, d)
        fin = np.isfinite(dd[:, 1:]) & np.isfinite(dd[:, :-1])
        with np.errstate(invalid="ignore"):  # inf - inf on pad tails
            if (np.where(fin, np.diff(dd, axis=1), 0.0) < 0).any():
                problems.append("rows not sorted by distance")
    if problems:
        raise SanitizerError(
            f"post-flush table scan failed{where}: " + "; ".join(problems)
        )


# ---------------------------------------------------------------------------
# aliasing sanitizer: poisoned kernels vs host oracles
# ---------------------------------------------------------------------------


def check_kernel_aliasing(*, k: int = 4, seed: int = 0, interpret: bool = True) -> None:
    """Replay the aliased Pallas kernels on poisoned inputs vs ref oracles.

    Poison pattern: pad neighbor slots carry huge finite garbage behind
    their -1 ids, the dummy row holds NaN-free trap values, and the
    donated (aliased) table operand is a *separate copy* whose trap slots
    differ from the read operand's — any read through the wrong operand or
    an unmasked pad slot shows up as an exact-equality miss vs the oracle.
    """
    from repro.kernels import ref
    from repro.kernels.frontier_relax import frontier_relax_pallas
    from repro.kernels.sweep_merge import sweep_merge_pallas

    rng = np.random.default_rng(seed)
    trap = np.float32(7e7)  # finite, absurd, impossible to produce legally

    # --- sweep_merge: (chunk, t) gather/scatter over the live tables -------
    n, chunk, t, e = 12, 4, 3, 2
    n1 = n + 1
    # level-schedule contract: target rows and neighbor rows are disjoint
    # within a call (targets even, neighbors odd)
    nbr = (rng.integers(0, n // 2, (chunk, t)) * 2 + 1).astype(np.int32)
    nbr[0, -1] = -1  # a padded neighbor slot
    verts = np.arange(chunk, dtype=np.int32) * 2
    w = rng.uniform(0.5, 2.0, (chunk, t)).astype(np.float32)
    w[nbr < 0] = trap  # poisoned: must be masked by the id, not the weight
    ex_ids = np.full((n1, e), -1, np.int32)
    ex_ids[: n // 2] = rng.integers(0, n, (n // 2, e), dtype=np.int32)
    ex_d = np.where(ex_ids >= 0, rng.uniform(0, 3, (n1, e)), trap).astype(np.float32)
    vk_ids = rng.integers(0, n, (n1, k), dtype=np.int32)
    vk_d = np.sort(rng.uniform(0, 5, (n1, k)), axis=1).astype(np.float32)
    vk_ids[-1] = -1
    vk_d[-1] = trap  # poisoned dummy row: reads of it must be id-masked

    want = ref.sweep_merge_ref(
        jnp.asarray(nbr), jnp.asarray(verts), jnp.asarray(w),
        jnp.asarray(ex_ids), jnp.asarray(ex_d),
        jnp.asarray(vk_ids), jnp.asarray(vk_d), k=k,
    )
    got = sweep_merge_pallas(
        jnp.asarray(nbr), jnp.asarray(verts), jnp.asarray(w),
        jnp.asarray(ex_ids), jnp.asarray(ex_d),
        jnp.asarray(vk_ids), jnp.asarray(vk_d),  # donated copy
        k=k, interpret=interpret,
    )
    for name, g, wnt in (("ids", got[0], want[0]), ("dists", got[1], want[1])):
        g = np.asarray(g)
        if not np.array_equal(g, wnt):
            bad = int((g != wnt).sum())
            raise SanitizerError(
                f"sweep_merge diverges from ref oracle on poisoned buffers "
                f"({name}: {bad} cells) — aliased-operand read or pad-mask bug"
            )

    # --- frontier_relax: aliased (n+1, B) scatter, Jacobi read discipline --
    r, tt, b = 5, 3, 4
    nbr2 = rng.integers(0, n, (r, tt), dtype=np.int32)
    nbr2[1, -1] = -1
    rows = rng.choice(n, r, replace=False).astype(np.int32)
    w2 = rng.uniform(0.5, 2.0, (r, tt)).astype(np.float32)
    w2[nbr2 < 0] = trap
    dist = rng.uniform(0, 4, (n1, b)).astype(np.float32)
    dist[-1] = np.inf  # dummy row
    kth = np.full(n1, 3.0, np.float32)
    kth[-1] = np.inf
    src = rng.integers(0, n, b, dtype=np.int32)

    want2 = ref.frontier_relax_ref(
        jnp.asarray(nbr2), jnp.asarray(rows), jnp.asarray(w2),
        jnp.asarray(dist), jnp.asarray(kth), jnp.asarray(src),
    )
    got2 = frontier_relax_pallas(
        jnp.asarray(nbr2), jnp.asarray(rows), jnp.asarray(w2),
        jnp.asarray(dist), jnp.asarray(kth), jnp.asarray(src),
        interpret=interpret,
    )
    got2 = np.asarray(got2)
    if not np.array_equal(got2, np.asarray(want2, np.float32)):
        bad = int((got2 != np.asarray(want2, np.float32)).sum())
        raise SanitizerError(
            f"frontier_relax diverges from ref oracle on poisoned buffers "
            f"({bad} cells) — the Jacobi aliased-read discipline is broken"
        )
