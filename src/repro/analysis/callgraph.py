"""AST call graph over the repo with jit/shard_map/pallas_call boundaries.

The static rail's foundation: REP001 ("no host materialization inside a
device program") is a property of *reachability* — ``np.asarray`` is fine in
flush orchestration code and fatal three frames below a ``jax.jit``. This
module builds, with nothing but the stdlib ``ast``:

* a table of every function/method in the analyzed tree, keyed
  ``module:qualname`` (nested defs use dotted qualnames, ``outer.inner``);
* the set of *device boundaries* — functions that become device programs:
  decorated with ``jax.jit`` (directly or through ``functools.partial``),
  wrapped by a ``jax.jit(f)`` / ``shard_map(f, ...)`` call, or passed as the
  kernel to ``pl.pallas_call`` (including through a local
  ``functools.partial`` alias);
* a conservative call graph: name calls resolve within the module, imported
  names resolve across analyzed modules (``from repro.kernels import ops``
  then ``ops.frontier_relax(...)``), and ``self.method()`` resolves to every
  analyzed method of that name (over-approximate on purpose — a lint rule
  must not lose an edge to polymorphism);
* the transitive *reachable* set from the boundaries, which is exactly
  "code that runs under a trace".

Resolution is intentionally name-based and over-approximate: a false edge
costs a spurious manual review, a missing edge costs a silent host sync on
a hot path. The latter is the bug class this whole subsystem exists for.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

# Call-expression heads that turn their first function argument into a
# device program. Matched on the attribute tail, so ``jax.jit``, ``jit``,
# ``pjit``, ``pl.pallas_call`` and ``jax.experimental.shard_map.shard_map``
# all resolve the same way.
_BOUNDARY_WRAPPERS = {"jit", "pjit", "shard_map", "pallas_call"}


def dotted_name(node: ast.AST) -> str:
    """Full dotted source text of a Name/Attribute chain, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def is_jit_expr(node: ast.AST) -> bool:
    """Is this expression a jit transform reference or a partial of one?

    Matches ``jax.jit``, ``jit``, ``pjit`` and
    ``functools.partial(jax.jit, ...)`` (any partial whose first argument is
    itself a jit reference).
    """
    name = dotted_name(node)
    if name.split(".")[-1] in ("jit", "pjit"):
        return True
    if isinstance(node, ast.Call) and dotted_name(node.func).split(".")[-1] == "partial":
        return bool(node.args) and is_jit_expr(node.args[0])
    return False


@dataclass
class FunctionInfo:
    key: str                     # "relpath:qualname"
    path: str                    # file the function lives in (relative)
    module: str                  # dotted module guess ("repro.kernels.ops")
    qualname: str
    node: ast.AST                # FunctionDef | AsyncFunctionDef
    boundary: str | None = None  # "jit" | "shard_map" | "pallas_call" | None
    calls: set[str] = field(default_factory=set)         # resolved keys
    method_calls: set[str] = field(default_factory=set)  # bare self.X names


@dataclass
class ModuleInfo:
    path: str
    module: str
    tree: ast.Module
    source: str
    # import alias -> dotted module ("ops" -> "repro.kernels.ops")
    import_aliases: dict[str, str] = field(default_factory=dict)
    # imported name -> "module.attr" ("insert_affected_set" ->
    # "repro.core.updates.insert_affected_set")
    from_imports: dict[str, str] = field(default_factory=dict)
    functions: dict[str, FunctionInfo] = field(default_factory=dict)  # qualname->


def module_name_for(path: str) -> str:
    """Best-effort dotted module for a file path (anchored at ``repro``)."""
    parts = [p for p in path.replace("\\", "/")[:-3].split("/") if p not in ("", ".")]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class _DefCollector(ast.NodeVisitor):
    """Pass 1: register every function/method (and decorator boundaries).

    Runs before the edge pass so a call to a function defined *later* in
    the file still resolves — module-level forward references are legal
    Python and common in top-down-styled code.
    """

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[str] = []

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        qual = ".".join(self.stack + [node.name]) if self.stack else node.name
        info = FunctionInfo(
            key=f"{self.mod.path}:{qual}",
            path=self.mod.path,
            module=self.mod.module,
            qualname=qual,
            node=node,
        )
        self.mod.functions[qual] = info
        for dec in node.decorator_list:
            if is_jit_expr(dec):
                info.boundary = "jit"
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()


class _ModuleScanner(ast.NodeVisitor):
    """Pass 2 per module: imports, boundary marks, call edges."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[str] = []       # qualname segments
        self.fn_stack: list[FunctionInfo] = []

    # -- imports --------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.mod.import_aliases[alias.asname or alias.name.split(".")[0]] = (
                alias.name
            )

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        base = node.module or ""
        for alias in node.names:
            local = alias.asname or alias.name
            self.mod.from_imports[local] = f"{base}.{alias.name}" if base else alias.name
            # "from repro.kernels import ops" imports a MODULE: record the
            # alias too so "ops.frontier_relax" resolves across modules
            self.mod.import_aliases.setdefault(local, f"{base}.{alias.name}")

    # -- functions (already registered by _DefCollector) ----------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        qual = ".".join(self.stack + [node.name]) if self.stack else node.name
        info = self.mod.functions[qual]
        self.stack.append(node.name)
        self.fn_stack.append(info)
        self.generic_visit(node)
        self.fn_stack.pop()
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    # -- calls ----------------------------------------------------------

    def _resolve_local(self, name: str) -> str | None:
        """A bare name, resolved against enclosing scopes then the module."""
        for depth in range(len(self.stack), -1, -1):
            qual = ".".join(self.stack[:depth] + [name]) if depth else name
            if qual in self.mod.functions:
                return qual
        return None

    def _record_callee(self, func: ast.AST) -> None:
        if not self.fn_stack:
            return
        info = self.fn_stack[-1]
        name = dotted_name(func)
        if not name:
            return
        head, _, rest = name.partition(".")
        if head in ("self", "cls") and rest and "." not in rest:
            info.method_calls.add(rest)
            return
        if "." not in name:
            local = self._resolve_local(name)
            if local is not None:
                info.calls.add(f"{self.mod.path}:{local}")
            elif name in self.mod.from_imports:
                info.calls.add(f"import:{self.mod.from_imports[name]}")
            return
        # module-attribute call through an import alias
        if head in self.mod.import_aliases and rest:
            info.calls.add(f"import:{self.mod.import_aliases[head]}.{rest}")

    def _mark_boundary_arg(self, node: ast.AST, kind: str) -> None:
        """Mark the function referenced by ``node`` as a device boundary."""
        if isinstance(node, ast.Lambda):
            return  # lambdas have no table entry; their body is tiny anyway
        if isinstance(node, ast.Call):
            # functools.partial(kernel, ...) -> the underlying function
            if dotted_name(node.func).split(".")[-1] == "partial" and node.args:
                self._mark_boundary_arg(node.args[0], kind)
            return
        name = dotted_name(node)
        if not name or "." in name:
            return
        local = self._resolve_local(name)
        if local is not None:
            fn = self.mod.functions[local]
            if fn.boundary is None:
                fn.boundary = kind
            # re-scan later marks via fixpoint in build_callgraph

    def visit_Call(self, node: ast.Call) -> None:
        self._record_callee(node.func)
        tail = dotted_name(node.func).split(".")[-1]
        if tail in _BOUNDARY_WRAPPERS and node.args:
            kind = "jit" if tail in ("jit", "pjit") else tail
            self._mark_boundary_arg(node.args[0], kind)
        if tail == "partial" and node.args and is_jit_expr(node):
            # functools.partial(jax.jit, static...)(f) handled at outer Call;
            # direct partial(jax.jit, f) marks f
            if len(node.args) >= 2:
                self._mark_boundary_arg(node.args[1], "jit")
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # f = jax.jit(g)  /  kernel = functools.partial(_kernel, k=k)
        if isinstance(node.value, ast.Call):
            inner = node.value
            if is_jit_expr(inner.func) and inner.args:
                self._mark_boundary_arg(inner.args[0], "jit")
        self.generic_visit(node)


@dataclass
class CallGraph:
    modules: dict[str, ModuleInfo]            # path -> module
    functions: dict[str, FunctionInfo]        # key -> info
    reachable: set[str]                       # keys reachable from boundaries

    def is_reachable(self, path: str, qualname: str) -> bool:
        return f"{path}:{qualname}" in self.reachable

    def boundaries(self) -> list[FunctionInfo]:
        return [f for f in self.functions.values() if f.boundary]


def build_callgraph(modules: dict[str, ModuleInfo]) -> CallGraph:
    """Scan every module, then close the boundary set over the call graph."""
    for mod in modules.values():
        _DefCollector(mod).visit(mod.tree)
        _ModuleScanner(mod).visit(mod.tree)

    functions: dict[str, FunctionInfo] = {}
    by_module_attr: dict[str, str] = {}   # "repro.kernels.ops.topk_merge" -> key
    by_method_name: dict[str, list[str]] = {}
    for mod in modules.values():
        for fn in mod.functions.values():
            functions[fn.key] = fn
            if mod.module:
                by_module_attr[f"{mod.module}.{fn.qualname}"] = fn.key
            tail = fn.qualname.split(".")[-1]
            if "." in fn.qualname:  # a method or nested def: callable by name
                by_method_name.setdefault(tail, []).append(fn.key)

    def resolve(edge: str) -> list[str]:
        if edge.startswith("import:"):
            target = edge[len("import:"):]
            if "repro" in target:
                target = target[target.index("repro"):]
            key = by_module_attr.get(target)
            return [key] if key else []
        return [edge] if edge in functions else []

    # BFS from the boundaries
    frontier = [f.key for f in functions.values() if f.boundary]
    reachable = set(frontier)
    while frontier:
        nxt: list[str] = []
        for key in frontier:
            fn = functions[key]
            targets: list[str] = []
            for edge in fn.calls:
                targets.extend(resolve(edge))
            for m in fn.method_calls:
                targets.extend(by_method_name.get(m, []))
            # a nested def inside a device function is itself device code
            prefix = f"{fn.path}:{fn.qualname}."
            targets.extend(k for k in functions if k.startswith(prefix))
            for t in targets:
                if t not in reachable:
                    reachable.add(t)
                    nxt.append(t)
        frontier = nxt
    return CallGraph(modules=modules, functions=functions, reachable=reachable)
