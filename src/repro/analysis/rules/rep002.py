"""REP002 — Pallas input/output aliasing contracts.

For every ``pl.pallas_call(...)(operands...)`` site, cross-checks the
``input_output_aliases`` dict against the kernel body:

* alias keys must name real operands, and must not name scalar-prefetch
  operands (operand indices count the prefetch args — the exact off-by-two
  this comment-only convention invited);
* alias values must name real outputs;
* the kernel must take enough positional refs for operands + outputs;
* an aliased input ref must not be read after the first write ("scatter")
  to its output ref — the frontier_relax hazard class: once the output
  block is emitted, the donated input buffer may already hold new values,
  so a later read sees post-round state and the Jacobi contract breaks.
  (Textually ordered by line; the runtime aliasing sanitizer in
  ``repro.analysis.sanitize`` covers the dynamic half of this contract.)

Kernel resolution follows bare names and ``functools.partial(kernel, ...)``
wrappers, including through a single local ``kernel = partial(...)``
assignment. ``num_scalar_prefetch`` is read off a ``PrefetchScalarGridSpec``
literal, also through one local assignment.
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import dotted_name
from repro.analysis.rules import Context, Finding, Rule


def _unwrap_partial(node: ast.AST, local_assigns: dict[str, ast.AST]) -> ast.AST:
    for _ in range(8):  # bounded: name -> assign -> partial -> name ...
        if isinstance(node, ast.Name) and node.id in local_assigns:
            node = local_assigns[node.id]
            continue
        if (
            isinstance(node, ast.Call)
            and dotted_name(node.func).split(".")[-1] == "partial"
            and node.args
        ):
            node = node.args[0]
            continue
        break
    return node


def _const_int(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _kwarg(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


class _RefUse(ast.NodeVisitor):
    """Line numbers where a named ref is read vs written (subscript store)."""

    def __init__(self, name: str):
        self.name = name
        self.reads: list[int] = []
        self.writes: list[int] = []

    def _target(self, t: ast.AST) -> None:
        if (
            isinstance(t, ast.Subscript)
            and isinstance(t.value, ast.Name)
            and t.value.id == self.name
        ):
            self.writes.append(t.lineno)
            self.visit(t.slice)  # index expressions still count as reads
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._target(e)
        else:
            self.visit(t)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._target(t)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._target(node.target)
        self.visit(node.value)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id == self.name:
            self.reads.append(node.lineno)


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    return [a.arg for a in list(fn.args.posonlyargs) + list(fn.args.args)]


def check(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for path, mod in sorted(ctx.modules.items()):
        for fn in mod.functions.values():
            if "." in fn.qualname and fn.qualname.rsplit(".", 1)[0] in mod.functions:
                continue  # analyzed as part of the enclosing function's scope
            local_assigns: dict[str, ast.AST] = {}
            sites: list[ast.Call] = []
            for node in ast.walk(fn.node):
                if (
                    isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                ):
                    local_assigns[node.targets[0].id] = node.value
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Call)
                    and dotted_name(node.func.func).split(".")[-1] == "pallas_call"
                ):
                    sites.append(node)
            for outer in sites:
                findings.extend(
                    _check_site(path, mod, outer, local_assigns)
                )
    return findings


def _check_site(path, mod, outer: ast.Call, local_assigns) -> list[Finding]:
    pc: ast.Call = outer.func  # the pl.pallas_call(...) expression
    out: list[Finding] = []
    n_ops = len(outer.args)

    aliases_node = _kwarg(pc, "input_output_aliases")
    if aliases_node is None:
        return out
    if not isinstance(aliases_node, ast.Dict):
        out.append(
            Finding(
                path, pc.lineno, pc.col_offset, "REP002",
                "input_output_aliases is not a dict literal; replint cannot "
                "verify the aliasing contract — inline the dict",
            )
        )
        return out
    aliases: dict[int, int] = {}
    for k_node, v_node in zip(aliases_node.keys, aliases_node.values):
        ki, vi = _const_int(k_node), _const_int(v_node)
        if ki is None or vi is None:
            out.append(
                Finding(
                    path, aliases_node.lineno, aliases_node.col_offset, "REP002",
                    "non-literal key/value in input_output_aliases",
                )
            )
            return out
        aliases[ki] = vi

    # scalar-prefetch count: grid_spec= a PrefetchScalarGridSpec (possibly
    # through one local assignment); a plain grid= means no prefetch args
    n_prefetch = 0
    gs = _kwarg(pc, "grid_spec")
    if gs is not None:
        if isinstance(gs, ast.Name) and gs.id in local_assigns:
            gs = local_assigns[gs.id]
        if (
            isinstance(gs, ast.Call)
            and dotted_name(gs.func).split(".")[-1] == "PrefetchScalarGridSpec"
        ):
            npf = _kwarg(gs, "num_scalar_prefetch")
            n_prefetch = _const_int(npf) or 0

    out_shape = _kwarg(pc, "out_shape")
    n_outs = len(out_shape.elts) if isinstance(out_shape, (ast.List, ast.Tuple)) else 1

    for ki, vi in aliases.items():
        if ki < n_prefetch:
            out.append(
                Finding(
                    path, pc.lineno, pc.col_offset, "REP002",
                    f"alias key {ki} names a scalar-prefetch operand "
                    f"(num_scalar_prefetch={n_prefetch}); operand indices count "
                    "the prefetch args, so aliasable operands start at "
                    f"{n_prefetch}",
                )
            )
        elif ki >= n_ops:
            out.append(
                Finding(
                    path, pc.lineno, pc.col_offset, "REP002",
                    f"alias key {ki} out of range: the call passes {n_ops} operands",
                )
            )
        if vi >= n_outs:
            out.append(
                Finding(
                    path, pc.lineno, pc.col_offset, "REP002",
                    f"alias value {vi} out of range: out_shape has {n_outs} outputs",
                )
            )
    if out:
        return out

    # resolve the kernel function for the read-after-scatter check
    kernel = _unwrap_partial(pc.args[0] if pc.args else ast.Constant(None), local_assigns)
    kname = dotted_name(kernel)
    kfn = mod.functions.get(kname) or next(
        (f for q, f in mod.functions.items() if q.split(".")[-1] == kname), None
    )
    if kfn is None or not isinstance(kfn.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return out
    params = _positional_params(kfn.node)
    if len(params) < n_ops + n_outs:
        out.append(
            Finding(
                path, kfn.node.lineno, kfn.node.col_offset, "REP002",
                f"kernel `{kname}` takes {len(params)} positional refs but the "
                f"pallas_call at line {pc.lineno} passes {n_ops} operands and "
                f"{n_outs} outputs",
            )
        )
        return out

    for ki, vi in aliases.items():
        in_param = params[ki]
        out_param = params[n_ops + vi]
        writes = _RefUse(out_param)
        writes.visit(kfn.node)
        reads = _RefUse(in_param)
        reads.visit(kfn.node)
        if not writes.writes:
            continue
        first_write = min(writes.writes)
        for ln in sorted(set(reads.reads)):
            if ln > first_write:
                out.append(
                    Finding(
                        path, ln, 0, "REP002",
                        f"aliased input ref `{in_param}` (operand {ki} -> output "
                        f"{vi}/`{out_param}`) is read after the first write to "
                        f"`{out_param}` at line {first_write}; after the scatter "
                        "the donated buffer may hold post-round values (the "
                        "frontier_relax Jacobi hazard) — read through a "
                        "non-aliased operand instead",
                    )
                )
    return out


RULE = Rule(
    code="REP002",
    summary="pallas_call input_output_aliases vs kernel ref reads (read-after-scatter)",
    check=check,
)
