"""Rule registry for replint.

A rule is a module-level object with a ``code`` ("REP001"), a one-line
``summary``, and a ``check(ctx) -> list[Finding]``. Rules are pure
functions of the parsed tree + call graph; they never import jax, so the
whole static rail runs on a bare-stdlib interpreter (the blocking
``analyze`` CI job relies on this).

Shared helpers here keep the rules honest about *scope*: ``iter_scope``
walks a function's own body without descending into nested defs (nested
defs get their own FunctionInfo and their own walk), and
``iter_module_scope`` walks exactly the expressions that execute at import
time (module body, class bodies, decorator expressions, default argument
values) — the surface REP005 polices.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.callgraph import CallGraph, ModuleInfo


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


@dataclass
class Context:
    """Everything a rule may look at."""

    modules: dict[str, ModuleInfo]  # path -> parsed module
    graph: CallGraph

    def numpy_aliases(self, mod: ModuleInfo) -> set[str]:
        return {a for a, m in mod.import_aliases.items() if m == "numpy"}

    def jnp_aliases(self, mod: ModuleInfo) -> set[str]:
        return {a for a, m in mod.import_aliases.items() if m == "jax.numpy"}


_NESTED = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)


def iter_scope(fn_node: ast.AST):
    """All nodes in a function's own scope, not entering nested defs."""
    todo = list(getattr(fn_node, "body", []))
    while todo:
        node = todo.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, _NESTED):
                todo.append(child)


def iter_module_scope(tree: ast.Module):
    """Nodes whose expressions execute at import time.

    Module statements and class bodies run directly; for function defs the
    decorator expressions and default argument values still evaluate at
    import, so those subtrees are walked too.
    """
    todo: list[ast.AST] = list(tree.body)
    while todo:
        node = todo.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            todo.extend(node.decorator_list)
            todo.extend(d for d in node.args.defaults if d is not None)
            todo.extend(d for d in node.args.kw_defaults if d is not None)
            continue
        if isinstance(node, ast.ClassDef):
            todo.extend(node.decorator_list)
            todo.extend(node.body)
            continue
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, _NESTED):
                todo.append(child)


@dataclass
class Rule:
    code: str
    summary: str
    check: "callable" = field(repr=False)


def all_rules() -> list[Rule]:
    from repro.analysis.rules import rep001, rep002, rep003, rep004, rep005

    return [rep001.RULE, rep002.RULE, rep003.RULE, rep004.RULE, rep005.RULE]
