"""REP004 — the id=int32 / dist=float32 contract at kernel boundaries.

Everything that crosses a kernel boundary in this repo is an (ids, dists)
pair: ids are int32, distances float32 (the paper's n*k*8-byte bound, and
the exact-equality oracle tests, both depend on it). A 64-bit dtype
sneaking into ``src/repro/kernels/`` either breaks under the default
x64-disabled config (silent truncation + a warning) or doubles the table
bytes under the x64 CI leg — and TPU Pallas has no i64/f64 lanes at all.

Flags, in kernel modules only: ``np.int64``/``jnp.float64``-style dtype
attributes, ``"int64"``/``"float64"`` dtype strings, and
``astype(jnp.int64)``-style casts (covered by the attribute scan).
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import dotted_name
from repro.analysis.rules import Context, Finding, Rule

_BAD_DTYPES = {"int64", "float64", "uint64"}


def check(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for path, mod in sorted(ctx.modules.items()):
        if "kernels/" not in path.replace("\\", "/"):
            continue
        dtype_roots = ctx.numpy_aliases(mod) | ctx.jnp_aliases(mod) | {"jax"}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr in _BAD_DTYPES:
                root = dotted_name(node).split(".")[0]
                if root in dtype_roots:
                    findings.append(
                        Finding(
                            path, node.lineno, node.col_offset, "REP004",
                            f"64-bit dtype `{dotted_name(node)}` in a kernel "
                            "module breaks the id=int32/dist=float32 boundary "
                            "contract (and TPU Pallas has no 64-bit lanes)",
                        )
                    )
            elif (
                isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value in _BAD_DTYPES
            ):
                findings.append(
                    Finding(
                        path, node.lineno, node.col_offset, "REP004",
                        f"64-bit dtype string \"{node.value}\" in a kernel "
                        "module breaks the id=int32/dist=float32 boundary contract",
                    )
                )
    return findings


RULE = Rule(
    code="REP004",
    summary="64-bit dtypes in kernel modules (id=int32/dist=float32 contract)",
    check=check,
)
