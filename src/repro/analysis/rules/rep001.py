"""REP001 — host materialization inside a device program.

Flags, in every function reachable from a jit/shard_map/pallas_call
boundary: ``.item()``, ``.tolist()``, ``float()/int()/bool()`` on traced
values, any call through a numpy alias (``np.asarray`` and friends), and
``jax.device_get``. Each of these forces the value to host: inside a
trace it either fails with a ConcretizationTypeError at best, or — the
bug class this rule exists for — silently splits one device program into
several with a blocking transfer between them.

``int()/float()/bool()`` are exempt when the argument is static metadata:
a literal, ``len(...)``, or anything rooted in ``.shape``/``.ndim``/
``.size``/``.dtype`` — those are Python values at trace time.
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import dotted_name
from repro.analysis.rules import Context, Finding, Rule, iter_scope

_HOST_METHODS = {"item", "tolist"}
_CASTS = {"float", "int", "bool", "complex"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


def _is_static_metadata(node: ast.AST) -> bool:
    """Is this expression a trace-time Python value (not a tracer)?"""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.BinOp, ast.UnaryOp)):
        kids = [c for c in ast.iter_child_nodes(node) if isinstance(c, ast.expr)]
        return all(_is_static_metadata(k) for k in kids if not isinstance(k, ast.operator))
    if isinstance(node, ast.Call):
        tail = dotted_name(node.func).split(".")[-1]
        return tail in ("len", "min", "max") and all(
            _is_static_metadata(a) for a in node.args
        )
    if isinstance(node, ast.Subscript):
        return _is_static_metadata(node.value)
    # anything rooted through .shape/.ndim/.size/.dtype is static
    cur = node
    while isinstance(cur, (ast.Attribute, ast.Subscript)):
        if isinstance(cur, ast.Attribute) and cur.attr in _STATIC_ATTRS:
            return True
        cur = cur.value
    return False


def check(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for key in sorted(ctx.graph.reachable):
        fn = ctx.graph.functions.get(key)
        if fn is None:
            continue
        mod = ctx.modules[fn.path]
        np_aliases = ctx.numpy_aliases(mod)
        for node in iter_scope(fn.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            tail = name.split(".")[-1]
            head = name.split(".")[0] if name else ""
            if isinstance(node.func, ast.Attribute) and node.func.attr in _HOST_METHODS:
                findings.append(
                    Finding(
                        fn.path,
                        node.lineno,
                        node.col_offset,
                        "REP001",
                        f"`.{node.func.attr}()` materializes to host inside "
                        f"device-reachable `{fn.qualname}` (reachable from a "
                        "jit/shard_map/pallas_call boundary)",
                    )
                )
            elif head in np_aliases and len(name.split(".")) > 1:
                findings.append(
                    Finding(
                        fn.path,
                        node.lineno,
                        node.col_offset,
                        "REP001",
                        f"numpy call `{name}(...)` inside device-reachable "
                        f"`{fn.qualname}` forces a host round-trip; use jnp or "
                        "hoist to the host orchestration layer",
                    )
                )
            elif name == "jax.device_get" or tail == "device_get":
                findings.append(
                    Finding(
                        fn.path,
                        node.lineno,
                        node.col_offset,
                        "REP001",
                        f"`jax.device_get` inside device-reachable `{fn.qualname}`",
                    )
                )
            elif (
                isinstance(node.func, ast.Name)
                and node.func.id in _CASTS
                and node.args
                and not _is_static_metadata(node.args[0])
            ):
                findings.append(
                    Finding(
                        fn.path,
                        node.lineno,
                        node.col_offset,
                        "REP001",
                        f"`{node.func.id}(...)` on a (possibly traced) value inside "
                        f"device-reachable `{fn.qualname}`; cast with .astype / "
                        "jnp, or compute from static .shape metadata",
                    )
                )
    return findings


RULE = Rule(
    code="REP001",
    summary="host materialization (.item/.tolist/float()/np.*) in jit-reachable code",
    check=check,
)
