"""REP003 — recompile risks.

Three sub-checks, all aimed at the 28->2 compile-count win the engine's
serving paths depend on:

* **jit-per-call** — ``jax.jit(...)`` (or ``functools.partial(jax.jit,
  ...)``) evaluated inside a function or loop body creates a *fresh*
  compiled callable on every call: every invocation recompiles. Hoist the
  wrapper to module scope or cache the result; a deliberate cached factory
  carries a ``# replint: disable=REP003(reason)`` pragma on its def line.
* **tracer-dependent branch** — a Python ``if``/``while`` on a non-static
  parameter of a jitted function fails at trace time (ConcretizationTypeError)
  or, when the value sneaks in as a weak-typed scalar, silently forks the
  compile cache. None-checks (``x is None``), ``isinstance`` tests and
  ``.shape``/``.ndim``/``.size``/``.dtype`` metadata are trace-time Python
  and exempt.
* **unhashable/bogus static args** — ``static_argnames`` naming a parameter
  that does not exist, or a static parameter whose default is a mutable
  literal (lists/dicts/sets are unhashable -> TypeError on the first call).
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import dotted_name, is_jit_expr
from repro.analysis.rules import Context, Finding, Rule, iter_scope

_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


def _static_names_from_call(call: ast.Call, params: list[str]) -> set[str] | None:
    """static_argnames/static_argnums of a jit application, None if opaque."""
    statics: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List)) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    statics.add(v.value)
                else:
                    return None
        elif kw.arg == "static_argnums":
            vals = kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List)) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    if v.value < len(params):
                        statics.add(params[v.value])
                else:
                    return None
    return statics


def _jit_applications(mod) -> list[tuple[ast.FunctionDef, ast.Call, object]]:
    """(function def, jit-application call, fn_info) for this module."""
    apps = []
    for fn in mod.functions.values():
        node = fn.node
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and is_jit_expr(dec):
                apps.append((node, dec, fn))
            elif is_jit_expr(dec) and not isinstance(dec, ast.Call):
                apps.append((node, None, fn))
    for walk_node in ast.walk(mod.tree):
        if not isinstance(walk_node, ast.Call):
            continue
        call, target = None, None
        if is_jit_expr(walk_node.func) and not isinstance(walk_node.func, ast.Call):
            # jax.jit(f, static_argnames=...)
            call, target = walk_node, walk_node.args[0] if walk_node.args else None
        elif isinstance(walk_node.func, ast.Call) and is_jit_expr(walk_node.func):
            # functools.partial(jax.jit, static_argnames=...)(f)
            call = walk_node.func
            target = walk_node.args[0] if walk_node.args else None
        if call is None or not isinstance(target, ast.Name):
            continue
        tfn = mod.functions.get(target.id) or next(
            (f for q, f in mod.functions.items() if q.split(".")[-1] == target.id),
            None,
        )
        if tfn is not None and isinstance(tfn.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            apps.append((tfn.node, call, tfn))
    return apps


def _names_in_test(test: ast.AST) -> set[str]:
    """Parameter names a branch actually depends on (metadata-exempted)."""
    if isinstance(test, ast.Call) and dotted_name(test.func) == "isinstance":
        return set()
    if isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    ):
        return set()  # `x is None` — trace-time Python
    exempt: set[str] = set()
    names: set[str] = set()
    for node in ast.walk(test):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            root = node.value
            while isinstance(root, (ast.Attribute, ast.Subscript)):
                root = root.value
            if isinstance(root, ast.Name):
                exempt.add(root.id)
        if isinstance(node, ast.Call):
            tail = dotted_name(node.func).split(".")[-1]
            if tail in ("len", "isinstance"):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Name):
                        exempt.add(sub.id)
    for node in ast.walk(test):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            names.add(node.id)
    return names - exempt


def check(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for path, mod in sorted(ctx.modules.items()):
        # (a) jit created inside a function body (worse still: inside a loop)
        for fn in mod.functions.values():
            loops = [
                n for n in iter_scope(fn.node) if isinstance(n, (ast.For, ast.While))
            ]
            for node in iter_scope(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                if not (is_jit_expr(node) or (
                    not isinstance(node.func, ast.Call) and is_jit_expr(node.func)
                )):
                    continue
                in_loop = any(
                    lp.lineno <= node.lineno <= (lp.end_lineno or lp.lineno)
                    for lp in loops
                )
                where = "a loop inside" if in_loop else "the body of"
                findings.append(
                    Finding(
                        path, node.lineno, node.col_offset, "REP003",
                        f"jit wrapper created in {where} `{fn.qualname}` — a "
                        "fresh compiled callable per call; hoist to module "
                        "scope or cache the result",
                    )
                )

        # (b)+(c) per jit application
        seen: set[int] = set()
        for fn_node, app_call, fn in _jit_applications(mod):
            if id(fn_node) in seen:
                continue
            seen.add(id(fn_node))
            params = [a.arg for a in list(fn_node.args.posonlyargs) + list(fn_node.args.args)]
            kwonly = [a.arg for a in fn_node.args.kwonlyargs]
            statics = (
                _static_names_from_call(app_call, params) if app_call is not None else set()
            )
            if statics is None:
                continue  # opaque static spec: cannot verify
            for s in sorted(statics):
                if s not in params and s not in kwonly:
                    findings.append(
                        Finding(
                            path, (app_call or fn_node).lineno,
                            (app_call or fn_node).col_offset, "REP003",
                            f"static_argnames names `{s}` which is not a "
                            f"parameter of `{fn.qualname}` — the jit spec is "
                            "silently dead",
                        )
                    )
            defaults = dict(
                zip(params[len(params) - len(fn_node.args.defaults):], fn_node.args.defaults)
            )
            defaults.update(
                {a.arg: d for a, d in zip(fn_node.args.kwonlyargs, fn_node.args.kw_defaults)
                 if d is not None}
            )
            for s in sorted(statics):
                d = defaults.get(s)
                if isinstance(d, (ast.List, ast.Dict, ast.Set)):
                    findings.append(
                        Finding(
                            path, d.lineno, d.col_offset, "REP003",
                            f"static parameter `{s}` of `{fn.qualname}` defaults "
                            "to a mutable (unhashable) literal — jit will raise "
                            "on the first call; use a tuple or None",
                        )
                    )
            nonstatic = (set(params) | set(kwonly)) - statics
            for node in iter_scope(fn_node):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                dep = sorted(_names_in_test(node.test) & nonstatic)
                if dep:
                    findings.append(
                        Finding(
                            path, node.lineno, node.col_offset, "REP003",
                            f"Python branch on non-static parameter(s) "
                            f"{', '.join(dep)} of jitted `{fn.qualname}` — "
                            "trace-time failure or a forked compile cache; mark "
                            "static or use lax.cond/jnp.where",
                        )
                    )
    return findings


RULE = Rule(
    code="REP003",
    summary="recompile risks: jit-per-call, tracer-dependent branches, bad static args",
    check=check,
)
