"""REP005 — module-level ``jnp`` computation.

A ``jnp.`` call at import time allocates a device buffer (pinning a
backend before the process picks one), runs before ``jax.config`` /
``JAX_*`` flags are applied, and in a multi-process setup happens on every
import of the module — none of which the author sees in a single-process
run. Constants belong in numpy (host) or inside the first traced call.

Metadata-only calls are exempt: ``jnp.iinfo``/``finfo``/``dtype``/
``issubdtype``/``result_type``/``promote_types`` inspect dtypes without
touching a device (e.g. the kernels' ``_INT_MAX = jnp.iinfo(jnp.int32).max``
sentinel).

The import-time surface is walked precisely: module body, class bodies,
decorator expressions, and default argument values all execute at import.
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import dotted_name
from repro.analysis.rules import Context, Finding, Rule, iter_module_scope

_METADATA_ONLY = {
    "iinfo", "finfo", "dtype", "issubdtype", "result_type", "promote_types",
}


def check(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for path, mod in sorted(ctx.modules.items()):
        jnp_roots = ctx.jnp_aliases(mod)
        if not jnp_roots:
            continue
        for node in iter_module_scope(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            parts = name.split(".")
            if parts[0] in jnp_roots and len(parts) > 1 and parts[-1] not in _METADATA_ONLY:
                findings.append(
                    Finding(
                        path, node.lineno, node.col_offset, "REP005",
                        f"module-level `{name}(...)` computes on device at "
                        "import time (allocates a buffer, pins a backend, "
                        "ignores late jax.config); use numpy or move inside "
                        "the traced function",
                    )
                )
    return findings


RULE = Rule(
    code="REP005",
    summary="module-level jnp computation (device work at import time)",
    check=check,
)
