"""Step builders: per-family train/serve steps with sharding trees attached.

Each make_* returns (fn, in_specs, out_specs_or_None, abstract_args) where
in_specs are PartitionSpec trees matching fn's positional args — everything
the launcher and the multi-pod dry-run need to jit, lower and compile.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import SDS
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as rec
from repro.models import transformer as tr
from repro.models.gnn import egnn as egnn_mod
from repro.models.gnn import gcn as gcn_mod
from repro.models.gnn import mace as mace_mod
from repro.models.gnn import nequip as nequip_mod
from repro.optim import adamw

GNN_MODULES = {
    "gcn-cora": gcn_mod,
    "egnn": egnn_mod,
    "nequip": nequip_mod,
    "mace": mace_mod,
}


def _abstract(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _replicated_like(tree):
    return jax.tree.map(lambda _: P(), tree)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_param_state(cfg: tr.TransformerConfig, rules: ShardingRules):
    pspecs = tr.param_specs(cfg, rules)
    params_abs = jax.eval_shape(functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(adamw.init, params_abs)
    ospecs = adamw.state_specs(pspecs)
    return params_abs, pspecs, opt_abs, ospecs


def make_lm_train(cfg: tr.TransformerConfig, rules: ShardingRules, opt_cfg=adamw.AdamWConfig()):
    params_abs, pspecs, opt_abs, ospecs = lm_param_state(cfg, rules)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(tr.loss_fn)(params, batch, cfg, rules)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    bspec = {"tokens": P(rules.batch, None), "labels": P(rules.batch, None)}
    in_specs = (pspecs, ospecs, bspec)
    out_specs = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    return train_step, in_specs, out_specs, (params_abs, opt_abs)


def make_lm_prefill(cfg: tr.TransformerConfig, rules: ShardingRules, max_len: int):
    params_abs, pspecs, _, _ = lm_param_state(cfg, rules)

    def prefill_step(params, tokens):
        return tr.prefill(params, tokens, cfg, max_len, rules)

    cspecs = tr.cache_specs(cfg, rules)
    in_specs = (pspecs, P(rules.batch, None))
    out_specs = (P(rules.batch, rules.ax(rules.tp, cfg.vocab)), cspecs)
    return prefill_step, in_specs, out_specs, (params_abs,)


def make_lm_decode(cfg: tr.TransformerConfig, rules: ShardingRules, cache_batch: int,
                   cache_len: int, *, cache_layout: str = "auto"):
    params_abs, pspecs, _, _ = lm_param_state(cfg, rules)
    cache_abs = jax.eval_shape(
        functools.partial(tr.init_cache, cfg, cache_batch, cache_len)
    )

    def decode(params, cache, tokens):
        return tr.decode_step(params, cache, tokens, cfg, rules)

    cspecs = tr.cache_specs(cfg, rules, cache_layout, batch_size=cache_batch)
    bax = rules.ax(rules.batch, cache_batch)
    in_specs = (pspecs, cspecs, P(bax))
    out_specs = (P(bax, rules.ax(rules.tp, cfg.vocab)), cspecs)
    return decode, in_specs, out_specs, (params_abs, cache_abs)


# ---------------------------------------------------------------------------
# GNN family — edge arrays sharded over every mesh axis, nodes over batch axes
# ---------------------------------------------------------------------------

def gnn_batch_specs(rules: ShardingRules, batch_abs: dict, node_shard: str = "batch") -> dict:
    """node_shard: 'batch' = nodes over the data axes (default);
    'all' = nodes over every mesh axis (aggregation becomes reduce-scatter
    instead of all-reduce — the §Perf hillclimb variant)."""
    all_axes = tuple(rules.mesh.axis_names)
    node_axes = all_axes if node_shard == "all" else rules.batch
    specs = {}
    for name, arr in batch_abs.items():
        if name == "edge_index":
            specs[name] = P(None, all_axes)
        elif name in ("node_feat", "pos", "species", "labels", "graph_id"):
            specs[name] = P(node_axes, *([None] * (len(arr.shape) - 1)))
        else:
            specs[name] = P(*([None] * len(arr.shape)))
    return specs


def make_gnn_train(arch_id: str, cfg, rules: ShardingRules, batch_abs: dict,
                   opt_cfg=adamw.AdamWConfig(), *, node_shard: str = "batch"):
    mod = GNN_MODULES[arch_id]
    params_abs = jax.eval_shape(functools.partial(mod.init_params, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = _replicated_like(params_abs)  # GNN params are small -> replicated
    opt_abs = jax.eval_shape(adamw.init, params_abs)
    ospecs = adamw.state_specs(pspecs)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(mod.loss_fn)(params, batch, cfg)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    bspec = gnn_batch_specs(rules, batch_abs, node_shard)
    in_specs = (pspecs, ospecs, bspec)
    out_specs = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    return train_step, in_specs, out_specs, (params_abs, opt_abs)


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------

def recsys_param_state(cfg, rules: ShardingRules):
    pspecs = rec.param_specs(cfg, rules)
    params_abs = jax.eval_shape(functools.partial(rec.init_params, cfg=cfg), jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(adamw.init, params_abs)
    ospecs = adamw.state_specs(pspecs)
    return params_abs, pspecs, opt_abs, ospecs


def make_recsys_train(cfg, rules: ShardingRules, opt_cfg=adamw.AdamWConfig()):
    params_abs, pspecs, opt_abs, ospecs = recsys_param_state(cfg, rules)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(rec.loss_fn)(params, batch, cfg)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    bspec = {"sparse_ids": P(rules.batch, None, None), "labels": P(rules.batch)}
    in_specs = (pspecs, ospecs, bspec)
    out_specs = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    return train_step, in_specs, out_specs, (params_abs, opt_abs)


def make_recsys_forward(cfg, rules: ShardingRules):
    params_abs, pspecs, _, _ = recsys_param_state(cfg, rules)

    def fwd(params, batch):
        return rec.forward(params, batch, cfg)

    bspec = {"sparse_ids": P(rules.batch, None, None), "labels": P(rules.batch)}
    return fwd, (pspecs, bspec), P(rules.batch), (params_abs,)


def make_recsys_retrieval(cfg, rules: ShardingRules, n_candidates: int, k: int = 100):
    params_abs, pspecs, _, _ = recsys_param_state(cfg, rules)

    def retrieve(params, batch):
        # dry-run path: the jnp reference form (the Pallas kernel is the
        # device hot path; XLA lowers this identically for roofline terms)
        return rec.retrieval_score(params, dict(batch, n_candidates=n_candidates), cfg,
                                   k=k, use_pallas=False)

    bspec = {"sparse_ids": P(None, None, None)}
    return retrieve, (pspecs, bspec), None, (params_abs,)


# ---------------------------------------------------------------------------
# KNN-Index (the paper) — distributed build sweep + sharded serving
# ---------------------------------------------------------------------------

def make_knn_build(cfg, rules: ShardingRules, use_pallas: bool = False,
                   *, contiguous: bool = False):
    """contiguous=True is the §Perf variant: vertices are renumbered by
    (level, position) on the host, so each level's results land in one
    dynamic-update-slice instead of a scatter — in-place with donation."""
    if contiguous:
        def step(level_start, nbr, w, extra_ids, extra_d, vk_ids, vk_d):
            s, t = nbr.shape
            valid = nbr >= 0
            nbr_c = jnp.where(valid, nbr, vk_ids.shape[0] - 1)
            g_ids = vk_ids[nbr_c]
            g_d = w[..., None] + vk_d[nbr_c]
            g_ids = jnp.where(valid[..., None], g_ids, -1)
            cand_ids = jnp.concatenate([g_ids.reshape(s, t * cfg.k), extra_ids], axis=1)
            cand_d = jnp.concatenate([g_d.reshape(s, t * cfg.k), extra_d], axis=1)
            from repro.kernels import ops as kops

            m_ids, m_d = kops.topk_merge(cand_ids, cand_d, cfg.k, use_pallas=use_pallas)
            vk_ids = jax.lax.dynamic_update_slice(vk_ids, m_ids, (level_start, 0))
            vk_d = jax.lax.dynamic_update_slice(vk_d, m_d, (level_start, 0))
            return vk_ids, vk_d

        flat = tuple(rules.mesh.axis_names)
        in_specs = (P(), P(flat, None), P(flat, None), P(flat, None), P(flat, None),
                    P(None, None), P(None, None))
        out_specs = (P(None, None), P(None, None))
        return step, in_specs, out_specs, None

    def step(verts, nbr, w, extra_ids, extra_d, vk_ids, vk_d):
        s, t = nbr.shape
        valid = nbr >= 0
        nbr_c = jnp.where(valid, nbr, vk_ids.shape[0] - 1)
        g_ids = jnp.where(valid[..., None], vk_ids[nbr_c], -1)
        g_d = w[..., None] + vk_d[nbr_c]
        cand_ids = jnp.concatenate([g_ids.reshape(s, t * cfg.k), extra_ids], axis=1)
        cand_d = jnp.concatenate([g_d.reshape(s, t * cfg.k), extra_d], axis=1)
        from repro.kernels import ops as kops

        m_ids, m_d = kops.topk_merge(cand_ids, cand_d, cfg.k, use_pallas=use_pallas)
        return vk_ids.at[verts].set(m_ids), vk_d.at[verts].set(m_d)

    flat = tuple(rules.mesh.axis_names)
    in_specs = (P(flat), P(flat, None), P(flat, None), P(flat, None), P(flat, None),
                P(None, None), P(None, None))
    out_specs = (P(None, None), P(None, None))
    return step, in_specs, out_specs, None


def make_knn_serve(cfg, rules: ShardingRules):
    def serve(vk_ids, vk_d, queries):
        return vk_ids[queries], vk_d[queries]

    flat = tuple(rules.mesh.axis_names)
    in_specs = (P(flat, None), P(flat, None), P(None))
    out_specs = (P(None, None), P(None, None))
    return serve, in_specs, out_specs, None
