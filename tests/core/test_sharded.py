"""ShardedQueryEngine: vertex-sharded multi-device serving vs the scalar engine.

The sharded engine's contract is *exact* equivalence, not just tie-tolerant
``indices_equivalent``: per-shard routing returns bit-identical query results,
and every flush lands on bit-identical tables (the per-row candidate multisets
and the merge are the same math, only partitioned). These tests run at every
shard count the visible device pool allows — under plain tier-1 CI that is a
single shard; the multi-device CI job forces 8 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) so shard counts
{1, 2, 4, 8} all execute, and that job fails if this module is skipped.
"""
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.core.sharded import ShardedQueryEngine, make_mesh, shard_tables
from repro.graph.generators import pick_objects, random_connected_graph, road_network

DEVICES = len(jax.devices())
SHARD_COUNTS = [s for s in (1, 2, 4, 8) if s <= DEVICES]


def _setup(grid=12, mu=0.15, k=6, seed=0, shards=1):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    plain = knn.QueryEngine.from_index(idx, objects, bn=bn)
    sharded = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=shards)
    return g, objects, bn, plain, sharded


def _tables_equal(a, b) -> bool:
    ia, ib = a.to_index(), b.to_index()
    return np.array_equal(ia.ids, ib.ids) and np.array_equal(ia.dists, ib.dists)


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_query_routing_bit_identical(shards):
    """Random batches spanning shard boundaries: same ids AND same dists."""
    g, objects, bn, plain, sharded = _setup(shards=shards)
    rng = np.random.default_rng(1)
    r = sharded.shard_rows
    # boundary-heavy traffic: first/last rows of every shard + uniform fill
    # + out-of-range ids, which must get the scalar gather's jnp semantics
    # (negatives wrap once from the table end, so -1 reads the dummy row ->
    # pad sentinel and -3 reads row n-2; ids >= n clamp to the dummy row)
    edges = np.concatenate(
        [np.arange(0, g.n, r), np.arange(r - 1, g.n, r), rng.integers(0, g.n, 128),
         [-3, -1, g.n, g.n + 7]]
    ).astype(np.int32)
    for us in (edges, rng.integers(0, g.n, size=257).astype(np.int32)):
        pi, pd = plain.query_batch(us)
        si, sd = sharded.query_batch(us)
        assert np.array_equal(np.asarray(pi), np.asarray(si))
        assert np.array_equal(np.asarray(pd), np.asarray(sd))
        ks = rng.integers(1, plain.k + 1, size=len(us)).astype(np.int32)
        pi, pd = plain.query_batch(us, ks)
        si, sd = sharded.query_batch(us, ks)
        assert np.array_equal(np.asarray(pi), np.asarray(si))
        assert np.array_equal(np.asarray(pd), np.asarray(sd))


@settings(max_examples=10, deadline=None)
@given(st.tuples(
    st.integers(min_value=8, max_value=36),
    st.integers(min_value=0, max_value=30),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=4),
))
def test_query_routing_property(p):
    """Property: on arbitrary topologies, routed sharded queries are
    bit-identical to the plain gather for random batches."""
    n, extra, seed, k = p
    rng = np.random.default_rng(seed)
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, 0.5, seed=seed)
    if len(objects) <= k:
        objects = np.arange(min(n, k + 2), dtype=np.int32)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    shards = SHARD_COUNTS[min(int(rng.integers(0, len(SHARD_COUNTS))),
                              len(SHARD_COUNTS) - 1)]
    if shards > n:
        shards = 1
    plain = knn.QueryEngine.from_index(idx, objects, bn=bn)
    sharded = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=shards)
    us = rng.integers(0, n, size=64).astype(np.int32)
    pi, pd = plain.query_batch(us)
    si, sd = sharded.query_batch(us)
    assert np.array_equal(np.asarray(pi), np.asarray(si))
    assert np.array_equal(np.asarray(pd), np.asarray(sd))


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_flush_exact_equivalence(shards):
    """Mixed staged updates (inserts/deletes/moves) flushed at several
    points: the sharded tables equal the scalar tables exactly after EVERY
    flush, and the final state matches a fresh rebuild."""
    g, objects, bn, plain, sharded = _setup(mu=0.2, shards=shards)
    k = plain.k
    rng = np.random.default_rng(7)
    mset = set(objects.tolist())
    for step in range(36):
        u = int(rng.integers(0, g.n))
        outside = sorted(set(range(g.n)) - mset)
        r = rng.random()
        if r < 0.3 and outside and len(mset) > k + 1:
            src = int(rng.choice(sorted(mset)))
            dst = int(rng.choice(outside))
            plain.stage_move(src, dst)
            sharded.stage_move(src, dst)
            mset.discard(src)
            mset.add(dst)
        elif u in mset and len(mset) > k + 1:
            plain.stage_delete(u)
            sharded.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            plain.stage_insert(u)
            sharded.stage_insert(u)
            mset.add(u)
        if step % 8 == 7:
            sp, ss = plain.flush_updates(), sharded.flush_updates()
            assert sp == ss
            assert _tables_equal(plain, sharded)
    plain.flush_updates()
    sharded.flush_updates()
    assert _tables_equal(plain, sharded)
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(fresh, sharded.to_index())
    assert set(sharded.objects.tolist()) == mset


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_sharded_device_frontier_matches_host_oracle(shards):
    """The shard-local checkIns frontier (boundary-crossing sources pinned
    to the first/last vertices of shard ranges) returns exactly the host
    oracle's affected rows, candidate ids and distances — and bit-identical
    output to the scalar engine's device frontier. Integer edge weights make
    every comparison exact, not tolerance-based."""
    g, objects, bn, plain, sharded = _setup(mu=0.2, shards=shards)
    rng = np.random.default_rng(11)
    outside = np.setdiff1d(np.arange(g.n), objects)
    r = sharded.shard_rows
    boundary = np.concatenate([np.arange(0, g.n, r), np.arange(r - 1, g.n, r)])
    srcs = [int(v) for v in boundary if v in set(outside.tolist())][:4]
    fill = [int(v) for v in rng.permutation(outside) if v not in srcs]
    srcs = sorted(srcs + fill[: max(0, 6 - len(srcs))])

    rows_p, ci_p, cd_p, rounds_p = plain._insert_frontier(srcs)
    rows_s, ci_s, cd_s, rounds_s = sharded._insert_frontier(srcs)
    assert rounds_p == rounds_s
    np.testing.assert_array_equal(rows_p, rows_s)
    np.testing.assert_array_equal(ci_p, ci_s)
    np.testing.assert_array_equal(cd_p, cd_s)

    from repro.core.updates import insert_affected_set

    kth = np.asarray(plain.tables[1][: g.n, -1], np.float64)
    per_row = {}
    for u in srcs:
        for v, d in insert_affected_set(bn, lambda x: float(kth[x]), u).items():
            per_row.setdefault(v, []).append((u, d))
    assert rows_s.tolist() == sorted(per_row)
    for i, v in enumerate(rows_s.tolist()):
        got = [(int(c), float(d)) for c, d in zip(ci_s[i], cd_s[i]) if c >= 0]
        assert got == per_row[v]


def test_reshard_on_load_roundtrip(tmp_path):
    """Save at 2 shards, load at 4 and at 1: all equivalent to the unsharded
    build, and the resharded engines keep serving and updating."""
    g = road_network(11, 13, seed=3)  # n not divisible by any shard count
    objects = pick_objects(g.n, 0.2, seed=3)
    bn = knn.build_bngraph(g)
    k = 5
    unsharded = knn.QueryEngine.build(bn, objects, k)
    writer = ShardedQueryEngine.build(bn, objects, k, shards=min(2, DEVICES))
    assert _tables_equal(unsharded, writer)
    path = os.path.join(tmp_path, "sharded.npz")
    writer.save(path)
    for shards in (min(4, DEVICES), 1):
        loaded = knn.load_engine(path, bn=bn, shards=shards)
        assert isinstance(loaded, ShardedQueryEngine)
        assert loaded.num_shards == shards
        assert knn.indices_equivalent(unsharded.to_index(), loaded.to_index())
        assert _tables_equal(unsharded, loaded)
        assert np.array_equal(loaded.objects, writer.objects)
        # the resharded engine still updates correctly
        outside = int(np.setdiff1d(np.arange(g.n), loaded.objects)[0])
        loaded.stage_insert(outside)
        loaded.flush_updates()
        fresh = knn_index_cons_plus(
            bn, np.array(sorted(set(loaded.objects.tolist()))), k
        )
        assert knn.indices_equivalent(fresh, loaded.to_index())
    # a scalar engine reads the same artifact (shard meta is provenance only)
    scalar = knn.load_engine(path, bn=bn)
    assert isinstance(scalar, knn.QueryEngine)
    assert _tables_equal(unsharded, scalar)


def test_sharded_fleet_workload():
    """The moving-fleet loop drives the sharded engine unchanged and lands on
    the same tables as the scalar engine on an identical movement trace."""
    from repro.workloads import drive_fleet_ticks

    g = road_network(10, 10, seed=4)
    bn = knn.build_bngraph(g)
    k = 4
    sim = knn.FleetSim(g, fleet_size=24, seed=4)
    init = sim.positions.copy()
    trace = [sim.tick() for _ in range(5)]
    plain = knn.QueryEngine.build(bn, init, k)
    sharded = ShardedQueryEngine.build(bn, init, k, shards=SHARD_COUNTS[-1])
    r_p = drive_fleet_ticks(plain, trace, batch=32, rng=np.random.default_rng(0))
    r_s = drive_fleet_ticks(sharded, trace, batch=32, rng=np.random.default_rng(0))
    assert r_p["moves"] == r_s["moves"] and r_p["ticks"] == r_s["ticks"]
    assert _tables_equal(plain, sharded)
    fresh = knn_index_cons_plus(bn, sim.positions, k)
    assert knn.indices_equivalent(fresh, sharded.to_index())


def test_build_sharded_engine_facade():
    g = road_network(8, 8, seed=5)
    objects = pick_objects(g.n, 0.2, seed=5)
    engine = knn.build_sharded_engine(g, objects, 4, shards=SHARD_COUNTS[-1])
    assert isinstance(engine, ShardedQueryEngine)
    fresh = knn_index_cons_plus(knn.build_bngraph(g), objects, 4)
    assert knn.indices_equivalent(fresh, engine.to_index())


def test_stats_report_shard_meta_and_padding():
    g, objects, bn, plain, sharded = _setup(shards=SHARD_COUNTS[-1])
    s = sharded.stats()
    assert s["num_shards"] == SHARD_COUNTS[-1]
    r = sharded.shard_rows
    padded = s["num_shards"] * (r + 1)
    assert s["padded_rows"] == padded
    assert s["row_padding_overhead"] == round((padded - g.n) / g.n, 4)


def test_save_refuses_pending_queue(tmp_path):
    g, objects, bn, plain, sharded = _setup(shards=1)
    sharded.stage_insert(int(np.setdiff1d(np.arange(g.n), objects)[0]))
    with pytest.raises(RuntimeError):
        sharded.save(os.path.join(tmp_path, "sharded.npz"))


def test_query_k_too_large_raises():
    _, _, _, _, sharded = _setup(shards=1)
    with pytest.raises(ValueError):
        sharded.query_batch(np.array([0, 1]), sharded.k + 1)


def test_make_mesh_validates_device_count():
    with pytest.raises(ValueError):
        make_mesh(DEVICES + 1)


def test_shard_tables_layout():
    """The sharded layout puts vertex v at row (v//R)*(R+1) + v%R with pad
    sentinels on dummy and overhang rows."""
    import jax.numpy as jnp

    n, k = 10, 3
    ids = jnp.arange((n + 1) * k, dtype=jnp.int32).reshape(n + 1, k)
    ids = ids.at[n].set(-1)
    d = ids.astype(jnp.float32)
    d = d.at[n].set(jnp.inf)
    mesh = make_mesh(min(4, DEVICES))
    s = mesh.devices.size
    r = -(-n // s)
    gi, gd = shard_tables(ids, d, n, mesh)
    assert gi.shape == (s * (r + 1), k)
    host = np.asarray(gi)
    for v in range(n):
        g_row = (v // r) * (r + 1) + v % r
        assert np.array_equal(host[g_row], np.asarray(ids[v]))
    covered = {(v // r) * (r + 1) + v % r for v in range(n)}
    for row in set(range(s * (r + 1))) - covered:
        assert (host[row] == -1).all()
