"""Collective halo exchange: device-resident cross-shard repair/frontier.

The sharded engine's multi-shard flush can run its halo two ways —
``halo = "host"`` routes neighbor rows through host set algebra and
``_fetch_rows``/``_fetch_send`` readbacks, ``halo = "collective"`` (the
default) moves the same rows shard-to-shard with capacity-padded
``all_gather`` multicasts and expands receiver sets on device. The contract
is *exact*: both modes (and the scalar oracle) land bit-identical tables at
every flush, the device receiver-set expansion equals the host CSR set
algebra as sets, and the collective path never calls the routed host
fetchers (monkeypatch-enforced) nor scales its host<->device transfer count
with the halo size (transfer-guard). Overflow past ``halo_capacity`` must
degrade to the routed path, not to wrong answers.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import knn
from repro.analysis import sanitize
from repro.core.reference import knn_index_cons_plus
from repro.core.sharded import ShardedQueryEngine
from repro.graph.generators import pick_objects, road_network

DEVICES = len(jax.devices())
SHARD_COUNTS = [s for s in (1, 2, 4, 8) if s <= DEVICES]


def _setup(grid=12, mu=0.15, k=6, seed=0, shards=1):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    plain = knn.QueryEngine.from_index(idx, objects, bn=bn)
    sharded = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=shards)
    return g, objects, bn, idx, plain, sharded


def _tables_equal(a, b) -> bool:
    ia, ib = a.to_index(), b.to_index()
    return np.array_equal(ia.ids, ib.ids) and np.array_equal(ia.dists, ib.dists)


def _boundary_actives(engine, n: int, rng, extra: int = 24) -> np.ndarray:
    """Active sets the expansion tests use: every shard-boundary vertex
    (first/last of each shard's range) plus random fill — the vertices
    whose BNS neighborhoods straddle owners."""
    starts = np.asarray(engine.routing.starts)
    edges = np.concatenate([starts, starts - 1, [n - 1]])
    edges = edges[(edges >= 0) & (edges < n)]
    return np.unique(
        np.concatenate([edges, rng.integers(0, n, extra)])
    ).astype(np.int32)


def _host_expand(engine, active: np.ndarray) -> np.ndarray:
    """The host CSR set-algebra oracle, via the base-class expansion."""
    engine._nbr_tables()
    return knn.QueryEngine._expand_receivers(engine, active)


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_device_expansion_matches_host_oracle(shards):
    """Device receiver-set expansion == host set algebra, exactly, for
    boundary-heavy active sets at every shard count."""
    g, objects, bn, idx, plain, sharded = _setup(shards=shards)
    rng = np.random.default_rng(7)
    sharded._nbr_tables()
    for _ in range(4):
        active = _boundary_actives(sharded, g.n, rng)
        got = sharded._expand_receivers_device(active)
        want = _host_expand(sharded, active)
        assert np.array_equal(got, want)
        # single vertices too (the degenerate receiver set)
        v = np.array([int(rng.integers(0, g.n))], np.int32)
        assert np.array_equal(
            sharded._expand_receivers_device(v), _host_expand(sharded, v)
        )


@settings(max_examples=8, deadline=None)
@given(st.tuples(
    st.integers(min_value=6, max_value=13),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=5),
))
def test_device_expansion_property(p):
    """Property: on continuous-weight road networks the device expansion is
    set-identical to the host oracle for arbitrary active sets — including
    shard-boundary sources — at a drawn shard count."""
    grid, seed, k = p
    rng = np.random.default_rng(seed)
    shards = SHARD_COUNTS[int(rng.integers(0, len(SHARD_COUNTS)))]
    g, objects, bn, idx, plain, sharded = _setup(
        grid=grid, k=k, seed=seed, shards=shards
    )
    sharded._nbr_tables()
    active = _boundary_actives(sharded, g.n, rng, extra=int(rng.integers(1, 48)))
    assert np.array_equal(
        sharded._expand_receivers_device(active), _host_expand(sharded, active)
    )


def _staged_script(engines, bn, idx, rng, steps, flush_p=0.3):
    """Replay one random insert/delete script through every engine (and the
    host oracle index), flushing at random points; yields after each flush.
    The live object set is read off the first engine, so repeated scripts
    (and boundary churn in between) compose."""
    from repro.core.updates import delete_object, insert_object

    mset = set(np.asarray(engines[0].objects).tolist())
    n = engines[0].n
    k = engines[0].k
    for _ in range(steps):
        u = int(rng.integers(0, n))
        if u in mset:
            if len(mset) <= k + 1:
                continue
            delete_object(bn, idx, u)
            for e in engines:
                e.stage_delete(u)
            mset.discard(u)
        else:
            insert_object(bn, idx, u)
            for e in engines:
                e.stage_insert(u)
            mset.add(u)
        if rng.random() < flush_p:
            for e in engines:
                e.flush_updates()
            yield
    for e in engines:
        e.flush_updates()
    yield


@pytest.mark.skipif(DEVICES < 2, reason="collective halo needs >= 2 devices")
@pytest.mark.parametrize("shards", [s for s in SHARD_COUNTS if s > 1])
def test_halo_three_way_bit_identical(shards):
    """Scalar oracle, collective halo and host halo land bit-identical
    tables at every flush of a shared staged script."""
    g, objects, bn, idx, plain, coll = _setup(shards=shards, seed=2)
    hosth = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=shards)
    hosth.halo = "host"
    assert coll.halo == "collective"
    rng = np.random.default_rng(11)
    for _ in _staged_script([plain, coll, hosth], bn, idx, rng, 30):
        assert _tables_equal(plain, coll)
        assert _tables_equal(plain, hosth)
    assert coll.stats()["halo_rounds_collective"] > 0
    assert coll.stats()["halo_fallbacks"] == 0


@pytest.mark.skipif(DEVICES < 2, reason="collective halo needs >= 2 devices")
def test_collective_flush_never_calls_host_fetchers():
    """Traffic guard: with the routed fetchers booby-trapped, collective
    flushes still complete — no host-mediated row exchange on this path."""
    g, objects, bn, idx, plain, coll = _setup(shards=2, seed=3)

    def boom(*a, **k):
        raise AssertionError("routed host fetcher called on collective path")

    coll._fetch_rows = boom
    coll._fetch_send = boom
    rng = np.random.default_rng(5)
    for _ in _staged_script([plain, coll], bn, idx, rng, 24):
        assert _tables_equal(plain, coll)
    assert coll.stats()["halo_rounds_collective"] > 0
    assert coll.stats()["halo_fallbacks"] == 0


@pytest.mark.skipif(DEVICES < 2, reason="collective halo needs >= 2 devices")
def test_halo_overflow_falls_back_to_routed_path():
    """A capacity the halo cannot fit under must degrade to the routed host
    path — counted in halo_fallbacks, never visible in the tables."""
    g, objects, bn, idx, plain, coll = _setup(shards=2, seed=4)
    coll.halo_capacity = 1  # below the 16-slot floor: every round overflows
    rng = np.random.default_rng(6)
    for _ in _staged_script([plain, coll], bn, idx, rng, 16):
        assert _tables_equal(plain, coll)
    assert coll.stats()["halo_fallbacks"] > 0
    assert coll.stats()["halo_rounds_collective"] == 0


@pytest.mark.skipif(DEVICES < 2, reason="collective halo needs >= 2 devices")
def test_collective_transfer_count_flat_in_halo_size():
    """Transfer guard: the collective flush's host<->device transfer count
    is a small constant per exchange round (plan uploads + one changed-mask
    readback) — it must not scale with the number of rows exchanged."""
    g, objects, bn, idx, plain, coll = _setup(grid=14, shards=4, seed=8)
    rng = np.random.default_rng(9)
    per_flush = []
    for steps in (4, 40):  # ~10x the staged rows -> ~same per-round count
        before = coll.stats()
        for u in rng.choice(
            np.setdiff1d(np.arange(g.n), coll.objects), steps, replace=False
        ):
            coll.stage_insert(int(u))
        with sanitize.count_transfers() as t:
            coll.flush_updates()
        after = coll.stats()
        rounds = max(
            1,
            after["halo_rounds_collective"] - before["halo_rounds_collective"],
        )
        assert after["halo_fallbacks"] == before["halo_fallbacks"]
        per_flush.append((t.h2d + t.d2h) / rounds)
    # flat: the big batch may not cost more transfers per round (allow one
    # extra for flush-constant overhead amortized over fewer rounds)
    assert per_flush[1] <= per_flush[0] + 1.0


@pytest.mark.skipif(DEVICES < 2, reason="collective halo needs >= 2 devices")
@pytest.mark.parametrize("halo", ["collective", "host"])
def test_updates_across_repartitioned_boundary(halo):
    """Regression (flat-index audit): after a mid-script repartition moves a
    shard boundary, deletes+inserts AT the moved boundary vertices must
    still localize through the new epoch's ShardLayout row map — a stale
    vertex->row cache would corrupt exactly these rows."""
    from repro.core.updates import delete_object, insert_object

    g, objects, bn, idx, plain, coll = _setup(shards=2, seed=12)
    coll.halo = halo
    rng = np.random.default_rng(13)
    for _ in _staged_script([plain, coll], bn, idx, rng, 8):
        pass
    # move the boundary to a deliberately lopsided split
    new_starts = (0, max(1, g.n // 3))
    coll.repartition(np.asarray(new_starts, np.int64))
    assert _tables_equal(plain, coll)
    # churn exactly at the moved boundary: the vertex on each side
    mset = set(int(v) for v in np.asarray(coll.objects))
    for v in (new_starts[1] - 1, new_starts[1], new_starts[1] + 1):
        if v in mset:
            delete_object(bn, idx, v)
            plain.stage_delete(v)
            coll.stage_delete(v)
            mset.discard(v)
        else:
            insert_object(bn, idx, v)
            plain.stage_insert(v)
            coll.stage_insert(v)
            mset.add(v)
    plain.flush_updates()
    coll.flush_updates()
    assert _tables_equal(plain, coll)
    # and a trailing random script on the new layout stays exact
    for _ in _staged_script([plain, coll], bn, idx, rng, 10):
        assert _tables_equal(plain, coll)


def test_halo_mode_validation():
    g, objects, bn, idx, plain, sharded = _setup(shards=1)
    with pytest.raises(knn.EngineConfigError):
        sharded.halo = "quantum"
    sharded.halo = "host"
    assert sharded.halo == "host"
