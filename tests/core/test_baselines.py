"""TEN-Index-lite baseline: correct kNN + H2H-dominated size profile."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.baselines import TENIndexLite
from repro.core.index import indices_equivalent
from repro.core.reference import dijkstra_cons
from repro.graph.generators import pick_objects, random_connected_graph, road_network


@settings(max_examples=12, deadline=None)
@given(
    st.tuples(
        st.integers(min_value=6, max_value=40),
        st.integers(min_value=0, max_value=40),
        st.integers(min_value=0, max_value=10_000),
        st.integers(min_value=1, max_value=5),
    )
)
def test_ten_lite_matches_oracle(p):
    n, extra, seed, k = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, 0.6, seed=seed)
    ten = TENIndexLite(g, objects, k)
    oracle = dijkstra_cons(g, objects, k)
    assert indices_equivalent(oracle, ten.build_knn_index())


def test_h2h_dominates_size():
    """The paper's motivation: H2H labels dwarf the kNN part of TEN-Index."""
    g = road_network(16, 16, seed=1)
    objects = pick_objects(g.n, 0.1, seed=1)
    ten = TENIndexLite(g, objects, 10)
    s = ten.size_entries()
    assert s["h2h_entries"] > 3 * s["ktnn_entries"]
