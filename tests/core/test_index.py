"""KNN-Index structure: O(k) query, progressive output, bounded size."""
import numpy as np

from repro.core.bngraph import build_bngraph
from repro.core.index import index_from_lists
from repro.core.reference import dijkstra_knn, knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network


def test_query_and_progressive():
    g = road_network(12, 12, seed=0)
    objects = pick_objects(g.n, 0.2, seed=0)
    k = 8
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    is_obj = np.zeros(g.n, bool)
    is_obj[objects] = True
    for u in range(0, g.n, 17):
        full = idx.query(u)
        oracle = dijkstra_knn(g, is_obj, k, u)
        assert [d for _, d in full] == [d for _, d in oracle]
        # progressive output yields the same prefix at every i (Theorem 4.4)
        prog = list(idx.query_progressive(u))
        assert prog == full
        # smaller-k queries answered from the same index (Section 4.2 remark)
        assert idx.query(u, 3) == full[:3]


def test_size_bound_is_exactly_nk():
    idx = index_from_lists(100, 7, [[(0, 1.0)]] * 100)
    assert idx.size_bytes() == 100 * 7 * 8  # Theorem 4.5: O(n*k)
