"""KNN-Index structure: O(k) query, progressive output, bounded size."""
import numpy as np
import pytest

from repro.core.bngraph import build_bngraph
from repro.core.index import index_from_lists, indices_equivalent
from repro.core.reference import dijkstra_knn, knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network


def test_query_and_progressive():
    g = road_network(12, 12, seed=0)
    objects = pick_objects(g.n, 0.2, seed=0)
    k = 8
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    is_obj = np.zeros(g.n, bool)
    is_obj[objects] = True
    for u in range(0, g.n, 17):
        full = idx.query(u)
        oracle = dijkstra_knn(g, is_obj, k, u)
        assert [d for _, d in full] == [d for _, d in oracle]
        # progressive output yields the same prefix at every i (Theorem 4.4)
        prog = list(idx.query_progressive(u))
        assert prog == full
        # smaller-k queries answered from the same index (Section 4.2 remark)
        assert idx.query(u, 3) == full[:3]


def test_size_bound_is_exactly_nk():
    idx = index_from_lists(100, 7, [[(0, 1.0)]] * 100)
    # Theorem 4.5: O(n*k) entries. The paper counts 4-byte ids + 4-byte
    # dists (the device tables); the host view stores float64 dists.
    assert idx.size_bytes(dist_bytes=4) == 100 * 7 * 8
    assert idx.size_bytes() == 100 * 7 * (4 + 8)


def test_query_k_beyond_index_k_raises():
    idx = index_from_lists(4, 3, [[(0, 1.0), (1, 2.0), (2, 3.0)]] * 4)
    with pytest.raises(ValueError):
        idx.query(0, 4)
    with pytest.raises(ValueError):
        list(idx.query_progressive(0, 4))
    assert idx.query(0, 3) == [(0, 1.0), (1, 2.0), (2, 3.0)]


def test_indices_equivalent_checks_ids_at_unique_distances():
    rows = [[(0, 1.0), (1, 2.0), (2, 3.0)], [(3, 1.0), (4, 1.0), (5, 9.0)]]
    a = index_from_lists(2, 3, rows)

    # a unique interior distance with a different id is NOT equivalent
    b = a.copy()
    b.ids[0, 1] = 7
    assert not indices_equivalent(a, b)

    # ids may swap across a genuine within-row distance tie
    c = a.copy()
    c.ids[1, 0], c.ids[1, 1] = 4, 3
    assert indices_equivalent(a, c)

    # the last slot of a FULL row may hide a boundary tie with the cut-off
    # (k+1)-th candidate, so its id is not checked
    d = a.copy()
    d.ids[0, 2] = 8
    assert indices_equivalent(a, d)

    # but in a short row (all objects present) the last id IS checked
    short = [[(0, 1.0), (1, 2.0)]]
    e = index_from_lists(1, 3, short)
    f = index_from_lists(1, 3, [[(0, 1.0), (6, 2.0)]])
    assert not indices_equivalent(e, f)

    # distances differing at all is never equivalent
    g = a.copy()
    g.dists[0, 1] = 2.5
    assert not indices_equivalent(a, g)
