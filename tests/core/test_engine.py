"""QueryEngine: batched device serving vs the scalar host reference.

The engine's contract (ISSUE-2 acceptance): query_batch matches per-row
KNNIndex.query exactly; staged batched updates are indices_equivalent to a
sequential replay through the core/updates.py oracle AND to a fresh rebuild;
save/load round-trips the artifact.
"""
import os

import numpy as np
import pytest

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.core.updates import delete_object, insert_object, move_object
from repro.graph.generators import pick_objects, random_connected_graph, road_network


def _setup(grid=12, mu=0.15, k=6, seed=0):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    engine = knn.QueryEngine.from_index(idx, objects, bn=bn)
    return g, objects, bn, idx, engine


def test_query_batch_matches_scalar_query():
    g, objects, bn, idx, engine = _setup()
    us = np.arange(g.n, dtype=np.int32)
    ids, d = engine.query_batch(us)
    ids, d = np.asarray(ids), np.asarray(d)
    for u in range(g.n):
        got = [(int(i), float(x)) for i, x in zip(ids[u], d[u]) if i >= 0]
        assert got == idx.query(u)


def test_query_batch_per_query_k_masking():
    g, objects, bn, idx, engine = _setup()
    rng = np.random.default_rng(0)
    us = rng.integers(0, g.n, size=64).astype(np.int32)
    ks = rng.integers(1, engine.k + 1, size=64).astype(np.int32)
    ids, d = engine.query_batch(us, ks)
    full_ids, full_d = engine.query_batch(us)
    ids, d = np.asarray(ids), np.asarray(d)
    full_ids, full_d = np.asarray(full_ids), np.asarray(full_d)
    for b in range(64):
        assert (ids[b, ks[b]:] == -1).all()
        assert np.isinf(d[b, ks[b]:]).all()
        assert (ids[b, : ks[b]] == full_ids[b, : ks[b]]).all()


def test_query_batch_k_too_large_raises():
    _, _, _, _, engine = _setup()
    with pytest.raises(ValueError):
        engine.query_batch(np.array([0, 1]), engine.k + 1)
    with pytest.raises(ValueError):
        engine.query_batch(np.array([0, 1]), np.array([1, engine.k + 1]))


def test_query_progressive_batch_prefixes():
    g, _, _, _, engine = _setup()
    us = np.arange(0, g.n, 5, dtype=np.int32)
    full_ids, full_d = engine.query_batch(us)
    full_ids, full_d = np.asarray(full_ids), np.asarray(full_d)
    seen = 0
    for i, (ids, d) in enumerate(engine.query_progressive_batch(us), start=1):
        assert ids.shape == (len(us), i)
        assert (np.asarray(ids) == full_ids[:, :i]).all()
        assert np.array_equal(np.asarray(d), full_d[:, :i])
        seen = i
    assert seen == engine.k


def test_staged_updates_match_oracle_and_rebuild():
    g, objects, bn, idx, engine = _setup(mu=0.2)
    k = engine.k
    rng = np.random.default_rng(3)
    mset = set(objects.tolist())
    oracle = idx.copy()
    for step in range(30):
        u = int(rng.integers(0, g.n))
        if u in mset and len(mset) > k + 1:
            delete_object(bn, oracle, u)
            engine.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            insert_object(bn, oracle, u)
            engine.stage_insert(u)
            mset.add(u)
        if step % 9 == 8:  # several flushes, several batch shapes
            engine.flush_updates()
    engine.flush_updates()
    got = engine.to_index()
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(oracle, got)
    assert knn.indices_equivalent(fresh, got)
    assert set(engine.objects.tolist()) == mset


def test_insert_then_delete_coalesces_to_noop():
    g, objects, bn, idx, engine = _setup()
    before = engine.to_index()
    outside = int(np.setdiff1d(np.arange(g.n), objects)[0])
    engine.stage_insert(outside)
    engine.stage_delete(outside)
    assert engine.queue_depth == 2
    stats = engine.flush_updates()
    assert stats["inserts"] == 0 and stats["deletes"] == 0
    assert stats["moves"] == 0 and stats["coalesced"] == 2
    after = engine.to_index()
    assert np.array_equal(before.ids, after.ids)
    assert np.array_equal(before.dists, after.dists)


def test_delete_then_insert_coalesces_to_noop():
    """del u then ins u: the final object set is unchanged, so the flush is a
    no-op (the index is a pure function of the object set)."""
    g, objects, bn, idx, engine = _setup()
    before = engine.to_index()
    present = int(objects[0])
    engine.stage_delete(present)
    engine.stage_insert(present)
    stats = engine.flush_updates()
    assert stats["inserts"] == 0 and stats["deletes"] == 0
    assert stats["moves"] == 0 and stats["coalesced"] == 2
    after = engine.to_index()
    assert np.array_equal(before.ids, after.ids)
    assert np.array_equal(before.dists, after.dists)


def test_move_chain_collapses_to_endpoint():
    """a->b then b->c coalesces to one net move a->c; the tables match a
    rebuild on the final object set and the stats report the folding."""
    g, objects, bn, idx, engine = _setup(mu=0.2)
    mset = set(objects.tolist())
    a = int(objects[0])
    outside = np.setdiff1d(np.arange(g.n), objects)
    b, c = int(outside[0]), int(outside[1])
    engine.stage_move(a, b)
    engine.stage_move(b, c)
    assert engine.queue_depth == 2
    stats = engine.flush_updates()
    assert stats["moves"] == 1 and stats["coalesced"] == 1
    assert stats["inserts"] == 0 and stats["deletes"] == 0
    mset.discard(a)
    mset.add(c)
    assert set(engine.objects.tolist()) == mset
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), engine.k)
    assert knn.indices_equivalent(fresh, engine.to_index())


def test_move_chain_returning_home_is_noop():
    g, objects, bn, idx, engine = _setup()
    before = engine.to_index()
    a = int(objects[0])
    b = int(np.setdiff1d(np.arange(g.n), objects)[0])
    engine.stage_move(a, b)
    engine.stage_move(b, a)
    stats = engine.flush_updates()
    assert stats["inserts"] == stats["deletes"] == stats["moves"] == 0
    assert stats["coalesced"] == 2
    after = engine.to_index()
    assert np.array_equal(before.ids, after.ids)
    assert np.array_equal(before.dists, after.dists)


def test_stage_move_matches_oracle():
    g, objects, bn, idx, engine = _setup(mu=0.2)
    oracle = idx.copy()
    src = int(objects[3])
    dst = int(np.setdiff1d(np.arange(g.n), objects)[0])
    engine.stage_move(src, dst)
    stats = engine.flush_updates()
    assert stats["moves"] == 1 and stats["coalesced"] == 0
    move_object(bn, oracle, src, dst)
    assert knn.indices_equivalent(oracle, engine.to_index())
    assert engine.stats()["moves_applied"] == 1


def test_stage_move_validation():
    g, objects, bn, idx, engine = _setup()
    present, present2 = int(objects[0]), int(objects[1])
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    with pytest.raises(ValueError):
        engine.stage_move(absent, present)   # source must be present
    with pytest.raises(ValueError):
        engine.stage_move(present, present2)  # destination must be absent
    with pytest.raises(ValueError):
        engine.stage_move(present, present)   # no self-move
    with pytest.raises(ValueError):
        engine.stage_move(present, g.n + 3)   # destination in range
    # staging state is what validation sees: after a move the source is
    # stageable as a destination and vice versa
    engine.stage_move(present, absent)
    engine.stage_move(present2, present)
    assert engine.queue_depth == 2
    engine.flush_updates()


def test_stage_validation():
    g, objects, bn, idx, engine = _setup()
    present = int(objects[0])
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    with pytest.raises(ValueError):
        engine.stage_insert(present)
    with pytest.raises(ValueError):
        engine.stage_delete(absent)
    with pytest.raises(ValueError):
        engine.stage_insert(g.n + 5)
    # staging state, not just flushed state, is what validation sees
    engine.stage_delete(present)
    with pytest.raises(ValueError):
        engine.stage_delete(present)
    engine.stage_insert(present)  # re-insert of the staged-deleted id is fine


def test_flush_device_frontier_no_host_loop_no_kth_readback(monkeypatch):
    """Traffic contract of the default flush pipeline: the checkIns frontier
    runs as batched device relaxation rounds — no per-object host heap
    search (``insert_affected_set``) and no (n,) k-th-column readback
    (``_table_kth``) may happen. Both entry points are booby-trapped and a
    mixed insert/delete/move flush must still land on the oracle tables."""
    import repro.core.engine as engine_mod

    g, objects, bn, idx, engine = _setup(mu=0.2)

    def boom(*a, **kw):
        raise AssertionError("host frontier path invoked by device pipeline")

    monkeypatch.setattr(engine_mod, "insert_affected_set", boom)
    monkeypatch.setattr(knn.QueryEngine, "_table_kth", boom)
    mset = set(objects.tolist())
    ins = [int(v) for v in np.setdiff1d(np.arange(g.n), objects)[:3]]
    dels = [int(objects[0]), int(objects[1])]
    mv_src, mv_dst = int(objects[2]), int(np.setdiff1d(np.arange(g.n), objects)[3])
    for u in ins:
        engine.stage_insert(u)
    for u in dels:
        engine.stage_delete(u)
    engine.stage_move(mv_src, mv_dst)
    stats = engine.flush_updates()
    assert stats["frontier_rounds"] >= 1
    mset = (mset | set(ins) | {mv_dst}) - set(dels) - {mv_src}
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), engine.k)
    assert knn.indices_equivalent(fresh, engine.to_index())


def test_host_and_device_frontier_pipelines_bit_identical():
    """``engine.frontier = "host"`` replays the per-object oracle pipeline;
    on integer-weight networks both pipelines must produce byte-identical
    tables and the same flush accounting (minus the round counter)."""
    g, objects, bn, idx, dev = _setup(mu=0.2)
    host = knn.QueryEngine.from_index(idx, objects, bn=bn)
    host.frontier = "host"
    rng = np.random.default_rng(5)
    mset = set(objects.tolist())
    for step in range(24):
        u = int(rng.integers(0, g.n))
        if u in mset and len(mset) > dev.k + 1:
            dev.stage_delete(u)
            host.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            dev.stage_insert(u)
            host.stage_insert(u)
            mset.add(u)
        if step % 7 == 6:
            sd, sh = dev.flush_updates(), host.flush_updates()
            assert sh["frontier_rounds"] == 0 and sd.pop("frontier_rounds") >= 0
            sh.pop("frontier_rounds")
            assert sd == sh
            a, b = dev.to_index(), host.to_index()
            assert np.array_equal(a.ids, b.ids)
            assert np.array_equal(a.dists, b.dists)


def test_frontier_mode_validated():
    """Only the two known pipelines are selectable; a typo must not
    silently fall through to the device path."""
    _, _, _, _, engine = _setup()
    with pytest.raises(ValueError, match="frontier"):
        engine.frontier = "Host"
    engine.frontier = "host"
    engine.frontier = "device"
    assert engine.frontier == "device"


def _both_engines():
    from repro.core.sharded import ShardedQueryEngine

    g, objects, bn, idx, engine = _setup()
    sharded = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=1)
    return g, objects, [engine, sharded]


def test_stage_insert_of_existing_object_raises_eagerly():
    """stage_insert of a present (or already-staged) object must fail AT
    STAGING time with a clear error, on both engines — not surface at flush
    or silently coalesce."""
    g, objects, engines = _both_engines()
    present = int(objects[0])
    for engine in engines:
        with pytest.raises(ValueError, match="already present"):
            engine.stage_insert(present)
        absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
        engine.stage_insert(absent)
        with pytest.raises(ValueError, match="already present"):
            engine.stage_insert(absent)  # staged-for-insert counts as present
        assert engine.queue_depth == 1  # failed stagings left no trace


def test_stage_delete_of_non_object_raises_eagerly():
    g, objects, engines = _both_engines()
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    for engine in engines:
        with pytest.raises(ValueError, match="absent"):
            engine.stage_delete(absent)
        present = int(objects[0])
        engine.stage_delete(present)
        with pytest.raises(ValueError, match="absent"):
            engine.stage_delete(present)  # staged-for-delete counts as absent
        assert engine.queue_depth == 1


def test_stage_move_to_same_vertex_raises_eagerly():
    g, objects, engines = _both_engines()
    present = int(objects[0])
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    for engine in engines:
        with pytest.raises(ValueError, match="source and destination"):
            engine.stage_move(present, present)
        # the self-move check fires even where membership checks would also
        # fail, so the error names the real mistake
        with pytest.raises(ValueError, match="source and destination"):
            engine.stage_move(absent, absent)
        assert engine.queue_depth == 0


def test_updates_require_bngraph():
    g, objects, bn, idx, _ = _setup()
    engine = knn.QueryEngine.from_index(idx, objects)  # no bn
    with pytest.raises(RuntimeError):
        engine.stage_insert(int(np.setdiff1d(np.arange(g.n), objects)[0]))


def test_save_load_roundtrip(tmp_path):
    g, objects, bn, idx, engine = _setup()
    path = os.path.join(tmp_path, "index.npz")
    engine.save(path)
    loaded = knn.load_engine(path, bn=bn)
    assert loaded.n == engine.n and loaded.k == engine.k
    assert np.array_equal(loaded.objects, engine.objects)
    a, b = engine.to_index(), loaded.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)
    # updates still work on the loaded engine
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    loaded.stage_insert(absent)
    loaded.flush_updates()
    oracle = idx.copy()
    insert_object(bn, oracle, absent)
    assert knn.indices_equivalent(oracle, loaded.to_index())


def test_save_refuses_pending_queue(tmp_path):
    """Documented policy: save with staged updates raises (no silent flush)."""
    g, objects, bn, idx, engine = _setup()
    engine.stage_insert(int(np.setdiff1d(np.arange(g.n), objects)[0]))
    with pytest.raises(RuntimeError):
        engine.save(os.path.join(tmp_path, "index.npz"))


def test_save_refuses_pending_move_queue(tmp_path):
    g, objects, bn, idx, engine = _setup()
    engine.stage_move(int(objects[0]), int(np.setdiff1d(np.arange(g.n), objects)[0]))
    with pytest.raises(RuntimeError):
        engine.save(os.path.join(tmp_path, "index.npz"))


def test_save_load_roundtrip_immediately_after_flush(tmp_path):
    """Flush-then-save round-trips bit-identically, and the loaded engine
    keeps serving and updating from exactly the flushed state."""
    g, objects, bn, idx, engine = _setup(mu=0.2)
    src = int(objects[2])
    dst = int(np.setdiff1d(np.arange(g.n), objects)[0])
    engine.stage_move(src, dst)
    with pytest.raises(RuntimeError):
        engine.save(os.path.join(tmp_path, "index.npz"))  # still pending
    engine.flush_updates()
    path = os.path.join(tmp_path, "index.npz")
    engine.save(path)
    loaded = knn.load_engine(path, bn=bn)
    a, b = engine.to_index(), loaded.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)
    assert np.array_equal(loaded.objects, engine.objects)
    mset = set(loaded.objects.tolist())
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), engine.k)
    assert knn.indices_equivalent(fresh, b)


def test_save_load_empty_object_set(tmp_path):
    """No objects: all-pad tables survive the round trip and the loaded
    engine can bootstrap the object set through staged inserts."""
    from repro.core.index import index_from_lists

    g = road_network(8, 8, seed=1)
    bn = knn.build_bngraph(g)
    k = 3
    empty = index_from_lists(g.n, k, [[] for _ in range(g.n)])
    engine = knn.QueryEngine.from_index(empty, np.array([], np.int32), bn=bn)
    ids, d = engine.query_batch(np.arange(g.n, dtype=np.int32))
    assert (np.asarray(ids) == -1).all() and np.isinf(np.asarray(d)).all()
    path = os.path.join(tmp_path, "empty.npz")
    engine.save(path)
    loaded = knn.load_engine(path, bn=bn)
    assert loaded.objects.size == 0
    assert np.array_equal(loaded.to_index().ids, empty.ids)
    # inserts into an empty index: kth is +inf everywhere, so the checkIns
    # frontier is the whole graph and every row gains the new object
    loaded.stage_insert(5)
    stats = loaded.flush_updates()
    assert stats["inserts"] == 1 and stats["rows_merged"] == g.n
    fresh = knn_index_cons_plus(bn, np.array([5]), k)
    assert knn.indices_equivalent(fresh, loaded.to_index())


def test_save_load_k1(tmp_path):
    """k=1: the smallest legal index round-trips and keeps updating."""
    g = road_network(8, 8, seed=2)
    objects = pick_objects(g.n, 0.15, seed=2)
    bn = knn.build_bngraph(g)
    engine = knn.build_engine(bn, objects, 1)
    path = os.path.join(tmp_path, "k1.npz")
    engine.save(path)
    loaded = knn.load_engine(path, bn=bn)
    assert loaded.k == 1
    a, b = engine.to_index(), loaded.to_index()
    assert np.array_equal(a.ids, b.ids)
    src = int(objects[0])
    dst = int(np.setdiff1d(np.arange(g.n), objects)[0])
    loaded.stage_move(src, dst)
    loaded.flush_updates()
    mset = set(objects.tolist()) - {src} | {dst}
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), 1)
    assert knn.indices_equivalent(fresh, loaded.to_index())


def test_load_legacy_artifact_infers_objects(tmp_path):
    """Pre-engine knn_build npz (ids/dists/k only): M = distance-0 entries."""
    g, objects, bn, idx, engine = _setup()
    path = os.path.join(tmp_path, "legacy.npz")
    np.savez(path, ids=idx.ids, dists=idx.dists, k=idx.k)
    loaded = knn.load_engine(path)
    assert set(loaded.objects.tolist()) == set(objects.tolist())


def test_engine_on_arbitrary_topology():
    """Engine flushes on a non-road random graph (property-test topology)."""
    n, k = 30, 3
    g = random_connected_graph(n, extra_edges=25, seed=7)
    objects = pick_objects(n, 0.5, seed=7)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    engine = knn.QueryEngine.from_index(idx, objects, bn=bn)
    rng = np.random.default_rng(7)
    mset = set(objects.tolist())
    for _ in range(20):
        u = int(rng.integers(0, n))
        if u in mset and len(mset) > k + 1:
            engine.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            engine.stage_insert(u)
            mset.add(u)
    engine.flush_updates()
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(fresh, engine.to_index())
