"""QueryEngine: batched device serving vs the scalar host reference.

The engine's contract (ISSUE-2 acceptance): query_batch matches per-row
KNNIndex.query exactly; staged batched updates are indices_equivalent to a
sequential replay through the core/updates.py oracle AND to a fresh rebuild;
save/load round-trips the artifact.
"""
import os

import numpy as np
import pytest

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.core.updates import delete_object, insert_object
from repro.graph.generators import pick_objects, random_connected_graph, road_network


def _setup(grid=12, mu=0.15, k=6, seed=0):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    engine = knn.QueryEngine.from_index(idx, objects, bn=bn)
    return g, objects, bn, idx, engine


def test_query_batch_matches_scalar_query():
    g, objects, bn, idx, engine = _setup()
    us = np.arange(g.n, dtype=np.int32)
    ids, d = engine.query_batch(us)
    ids, d = np.asarray(ids), np.asarray(d)
    for u in range(g.n):
        got = [(int(i), float(x)) for i, x in zip(ids[u], d[u]) if i >= 0]
        assert got == idx.query(u)


def test_query_batch_per_query_k_masking():
    g, objects, bn, idx, engine = _setup()
    rng = np.random.default_rng(0)
    us = rng.integers(0, g.n, size=64).astype(np.int32)
    ks = rng.integers(1, engine.k + 1, size=64).astype(np.int32)
    ids, d = engine.query_batch(us, ks)
    full_ids, full_d = engine.query_batch(us)
    ids, d = np.asarray(ids), np.asarray(d)
    full_ids, full_d = np.asarray(full_ids), np.asarray(full_d)
    for b in range(64):
        assert (ids[b, ks[b]:] == -1).all()
        assert np.isinf(d[b, ks[b]:]).all()
        assert (ids[b, : ks[b]] == full_ids[b, : ks[b]]).all()


def test_query_batch_k_too_large_raises():
    _, _, _, _, engine = _setup()
    with pytest.raises(ValueError):
        engine.query_batch(np.array([0, 1]), engine.k + 1)
    with pytest.raises(ValueError):
        engine.query_batch(np.array([0, 1]), np.array([1, engine.k + 1]))


def test_query_progressive_batch_prefixes():
    g, _, _, _, engine = _setup()
    us = np.arange(0, g.n, 5, dtype=np.int32)
    full_ids, full_d = engine.query_batch(us)
    full_ids, full_d = np.asarray(full_ids), np.asarray(full_d)
    seen = 0
    for i, (ids, d) in enumerate(engine.query_progressive_batch(us), start=1):
        assert ids.shape == (len(us), i)
        assert (np.asarray(ids) == full_ids[:, :i]).all()
        assert np.array_equal(np.asarray(d), full_d[:, :i])
        seen = i
    assert seen == engine.k


def test_staged_updates_match_oracle_and_rebuild():
    g, objects, bn, idx, engine = _setup(mu=0.2)
    k = engine.k
    rng = np.random.default_rng(3)
    mset = set(objects.tolist())
    oracle = idx.copy()
    for step in range(30):
        u = int(rng.integers(0, g.n))
        if u in mset and len(mset) > k + 1:
            delete_object(bn, oracle, u)
            engine.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            insert_object(bn, oracle, u)
            engine.stage_insert(u)
            mset.add(u)
        if step % 9 == 8:  # several flushes, several batch shapes
            engine.flush_updates()
    engine.flush_updates()
    got = engine.to_index()
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(oracle, got)
    assert knn.indices_equivalent(fresh, got)
    assert set(engine.objects.tolist()) == mset


def test_insert_then_delete_coalesces_to_noop():
    g, objects, bn, idx, engine = _setup()
    before = engine.to_index()
    outside = int(np.setdiff1d(np.arange(g.n), objects)[0])
    engine.stage_insert(outside)
    engine.stage_delete(outside)
    assert engine.queue_depth == 2
    stats = engine.flush_updates()
    assert stats["inserts"] == 0 and stats["deletes"] == 0
    after = engine.to_index()
    assert np.array_equal(before.ids, after.ids)
    assert np.array_equal(before.dists, after.dists)


def test_stage_validation():
    g, objects, bn, idx, engine = _setup()
    present = int(objects[0])
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    with pytest.raises(ValueError):
        engine.stage_insert(present)
    with pytest.raises(ValueError):
        engine.stage_delete(absent)
    with pytest.raises(ValueError):
        engine.stage_insert(g.n + 5)
    # staging state, not just flushed state, is what validation sees
    engine.stage_delete(present)
    with pytest.raises(ValueError):
        engine.stage_delete(present)
    engine.stage_insert(present)  # re-insert of the staged-deleted id is fine


def test_updates_require_bngraph():
    g, objects, bn, idx, _ = _setup()
    engine = knn.QueryEngine.from_index(idx, objects)  # no bn
    with pytest.raises(RuntimeError):
        engine.stage_insert(int(np.setdiff1d(np.arange(g.n), objects)[0]))


def test_save_load_roundtrip(tmp_path):
    g, objects, bn, idx, engine = _setup()
    path = os.path.join(tmp_path, "index.npz")
    engine.save(path)
    loaded = knn.load_engine(path, bn=bn)
    assert loaded.n == engine.n and loaded.k == engine.k
    assert np.array_equal(loaded.objects, engine.objects)
    a, b = engine.to_index(), loaded.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)
    # updates still work on the loaded engine
    absent = int(np.setdiff1d(np.arange(g.n), objects)[0])
    loaded.stage_insert(absent)
    loaded.flush_updates()
    oracle = idx.copy()
    insert_object(bn, oracle, absent)
    assert knn.indices_equivalent(oracle, loaded.to_index())


def test_save_refuses_pending_queue(tmp_path):
    g, objects, bn, idx, engine = _setup()
    engine.stage_insert(int(np.setdiff1d(np.arange(g.n), objects)[0]))
    with pytest.raises(RuntimeError):
        engine.save(os.path.join(tmp_path, "index.npz"))


def test_load_legacy_artifact_infers_objects(tmp_path):
    """Pre-engine knn_build npz (ids/dists/k only): M = distance-0 entries."""
    g, objects, bn, idx, engine = _setup()
    path = os.path.join(tmp_path, "legacy.npz")
    np.savez(path, ids=idx.ids, dists=idx.dists, k=idx.k)
    loaded = knn.load_engine(path)
    assert set(loaded.objects.tolist()) == set(objects.tolist())


def test_engine_on_arbitrary_topology():
    """Engine flushes on a non-road random graph (property-test topology)."""
    n, k = 30, 3
    g = random_connected_graph(n, extra_edges=25, seed=7)
    objects = pick_objects(n, 0.5, seed=7)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    engine = knn.QueryEngine.from_index(idx, objects, bn=bn)
    rng = np.random.default_rng(7)
    mset = set(objects.tolist())
    for _ in range(20):
        u = int(rng.integers(0, n))
        if u in mset and len(mset) > k + 1:
            engine.stage_delete(u)
            mset.discard(u)
        elif u not in mset:
            engine.stage_insert(u)
            mset.add(u)
    engine.flush_updates()
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(fresh, engine.to_index())
