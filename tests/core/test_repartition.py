"""Uneven shard ranges + repartition-on-flush (ISSUE 9 round-trip/chaos).

Four properties of the boundary machinery:

1. ``repartition`` (stage + flush) re-lays the tables under traffic-driven
   uneven boundaries with results bit-identical before/after, and pinned
   reads on the pre-repartition epoch keep serving under the OLD
   boundaries — per-epoch layout versioning, not a global swap.
2. Round-trip: an artifact saved under uneven boundaries reloads with the
   saved boundaries at the same shard count, resharding at a different
   count and through the scalar engine, all bit-identical — and staged
   updates on the reloaded engine still equal the scalar oracle.
3. Chaos: a kill at any repartition checkpoint (``pre-repartition`` /
   ``mid-repartition`` / ``pre-swap``) rolls the flush back to the OLD
   boundaries with the repartition still staged — never a torn layout —
   and the retry lands updates + boundaries in one epoch, byte-equal to
   an uncrashed twin.
4. Boundary-vector misuse raises the typed ``EngineConfigError``.

The multi-device CI leg's junit gate requires >= 3 of these cases to run
un-skipped; only the validation case is meaningful on a 1-device pool.
"""

import jax
import numpy as np
import pytest

from repro import knn
from repro.core.errors import EngineConfigError
from repro.core.partition import PartitionPlan, propose_starts

DEVICES = len(jax.devices())
NEEDS_MESH = pytest.mark.skipif(
    DEVICES < 2, reason="boundaries only move between real shards (>= 2 devices)"
)

PHASES = ["pre-repartition", "mid-repartition", "pre-swap"]


class SimulatedKill(Exception):
    """Raised by the chaos hook to model the process dying at this point."""


def _setup(seed=0, k=4):
    g = knn.road_network(10, 10, seed=seed)
    objects = knn.pick_objects(g.n, 0.3, seed=seed)
    bn = knn.build_bngraph(g)
    return g, bn, objects, k


def _skewed_starts(engine, n):
    # a heavy-headed histogram: the splitter narrows the first range hard,
    # so the proposal is guaranteed uneven for any shard count >= 2
    w = 1.0 / (1.0 + np.arange(n, dtype=np.float64))
    return propose_starts(w, engine.num_shards, n=n)


def _query(eng, us, epoch=None):
    ids, d = eng.query_batch(us, epoch=epoch)
    return np.asarray(ids), np.asarray(d)


@NEEDS_MESH
def test_repartition_bit_identical_and_pins_old_epochs():
    g, bn, objects, k = _setup()
    shards = min(4, DEVICES)
    eng = knn.build_sharded_engine(bn, objects, k, plan=PartitionPlan(shards=shards))
    us = np.arange(g.n)
    before_ids, before_d = _query(eng, us)
    e0 = eng.epoch
    starts = _skewed_starts(eng, g.n)
    assert eng.pending_repartition is None
    eng.repartition(starts)
    assert eng.epoch == e0 + 1
    assert eng.pending_repartition is None
    assert eng.routing.starts.tolist() == [int(s) for s in starts]
    after_ids, after_d = _query(eng, us)
    assert np.array_equal(before_ids, after_ids)
    assert np.array_equal(before_d, after_d)
    # pinned reads on the OLD epoch serve under the OLD boundaries
    old_ids, old_d = _query(eng, us, epoch=e0)
    assert np.array_equal(before_ids, old_ids)
    assert np.array_equal(before_d, old_d)
    s = eng.stats()
    assert s["uneven_ranges"] is True
    assert s["repartitions"] == 1
    assert s["shard_starts"] == [int(x) for x in starts]
    # updates flushed AFTER the repartition still equal the scalar oracle
    oracle = knn.build_engine(bn, objects, k)
    mset = set(int(o) for o in objects)
    oset = set(mset)
    knn.stage_random_updates(eng, mset, rng=7, count=6)
    knn.stage_random_updates(oracle, oset, rng=7, count=6)
    assert mset == oset
    eng.flush_updates()
    oracle.flush_updates()
    a, b = eng.to_index(), oracle.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)


@NEEDS_MESH
def test_repartition_roundtrip_save_load(tmp_path):
    g, bn, objects, k = _setup(seed=1)
    shards = min(4, DEVICES)
    eng = knn.build_sharded_engine(bn, objects, k, shards=shards)
    eng.repartition(_skewed_starts(eng, g.n))
    art = str(tmp_path / "uneven.npz")
    eng.save(art)
    us = np.arange(g.n)
    ref_ids, ref_d = _query(eng, us)

    # same shard count: the artifact's boundary vector is reused verbatim
    same = knn.load_engine(art, bn=bn, plan=PartitionPlan(shards=shards))
    assert same.routing.starts.tolist() == eng.routing.starts.tolist()
    assert same.stats()["uneven_ranges"] is True
    # different shard count (reshard) and the scalar engine both serve the
    # very same tables
    scalar = knn.load_engine(art, bn=bn)
    loaded = [same, scalar]
    if shards > 2:
        loaded.append(knn.load_engine(art, bn=bn, plan=PartitionPlan(shards=2)))
    for other in loaded:
        ids, d = _query(other, us)
        assert np.array_equal(ref_ids, ids)
        assert np.array_equal(ref_d, d)
    # staged updates on the reloaded uneven engine equal the scalar oracle
    mset = set(int(o) for o in objects)
    oset = set(mset)
    knn.stage_random_updates(same, mset, rng=3, count=6)
    knn.stage_random_updates(scalar, oset, rng=3, count=6)
    assert mset == oset
    same.flush_updates()
    scalar.flush_updates()
    a, b = same.to_index(), scalar.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)


@NEEDS_MESH
@pytest.mark.parametrize("phase", PHASES)
def test_kill_during_repartition_never_torn(phase):
    g, bn, objects, k = _setup(seed=2)
    shards = min(4, DEVICES)
    eng = knn.build_sharded_engine(bn, objects, k, shards=shards)
    twin = knn.build_sharded_engine(bn, objects, k, shards=shards)
    us = np.arange(g.n)
    mset = set(int(o) for o in objects)
    tset = set(mset)
    knn.stage_random_updates(eng, mset, rng=5, count=5)
    knn.stage_random_updates(twin, tset, rng=5, count=5)
    assert mset == tset
    starts = _skewed_starts(eng, g.n)
    old = eng.routing.starts.copy()
    e0 = eng.epoch
    eng.stage_repartition(starts)

    def hook(e, ph):
        if ph == phase:
            raise SimulatedKill(ph)

    eng.checkpoint_hook = hook
    with pytest.raises(SimulatedKill):
        eng.flush_updates()
    eng.checkpoint_hook = None
    # never torn: the OLD boundaries still serve, no epoch was published,
    # and the repartition (like the update batch) is still staged
    assert eng.routing.starts.tolist() == old.tolist()
    assert eng.epoch == e0
    assert eng.pending_repartition is not None
    assert eng.pending_repartition.tolist() == [int(x) for x in starts]
    ids0, d0 = _query(eng, us)
    tids, td = _query(twin, us)  # twin's batch is staged-not-flushed too
    assert np.array_equal(ids0, tids)
    assert np.array_equal(d0, td)
    # the retry lands the update batch AND the new boundaries in one epoch
    twin.stage_repartition(starts)
    eng.flush_updates()
    twin.flush_updates()
    assert eng.epoch == twin.epoch
    assert eng.routing.starts.tolist() == [int(x) for x in starts]
    assert eng.pending_repartition is None
    a, b = eng.to_index(), twin.to_index()
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)


def test_stage_repartition_validation():
    g, bn, objects, k = _setup(seed=3)
    eng = knn.build_sharded_engine(bn, objects, k, shards=1)
    with pytest.raises(EngineConfigError):
        eng.stage_repartition([0, 50])  # names 2 shards, engine has 1
    with pytest.raises(EngineConfigError):
        eng.stage_repartition([5])  # first boundary must be 0
    assert eng.pending_repartition is None
    eng.stage_repartition([0])  # a no-op relayout stages then clears
    eng.flush_updates()
    assert eng.pending_repartition is None
