"""BN-Graph tropical certificate (core/verify.py + the minplus kernel)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.core.verify import certificate, relaxation_stable
from repro.graph.generators import random_connected_graph


@settings(max_examples=10, deadline=None)
@given(st.tuples(st.integers(5, 35), st.integers(0, 40), st.integers(0, 1000)))
def test_bngraph_passes_certificate(p):
    n, extra, seed = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    bn = build_bngraph(g)
    cert = certificate(bn, use_pallas=False)
    assert cert["ok"], cert


def test_certificate_catches_corruption():
    g = random_connected_graph(20, extra_edges=15, seed=3)
    bn = build_bngraph(g)
    assert relaxation_stable(bn, use_pallas=False)
    # corrupt one edge weight upward -> a shorter two-hop path now exists
    for v in range(bn.n):
        sel = bn.lo_ids[v] >= 0
        if sel.sum() >= 2:
            bn.lo_w[v][np.argmax(sel)] += 100.0
            break
    assert not relaxation_stable(bn, use_pallas=False)


def test_certificate_with_pallas_kernel():
    g = random_connected_graph(24, extra_edges=12, seed=7)
    bn = build_bngraph(g)
    assert relaxation_stable(bn, use_pallas=True)
