"""PartitionPlan — the one partition-layout surface — and the argument audit.

Covers the unified value object end to end: spec-string parsing, constructor
validation, the legacy shards=/replication= shim (``resolve``) with
plan-vs-legacy mixing rejected, the histogram splitter ``propose_starts``,
and the typed-error audit ISSUE 9 demands — misuse raises ``QueryError`` /
``EngineConfigError`` / ``EpochError`` (never a bare TypeError/KeyError), and
``EngineConfigError`` stays a ``ValueError`` subclass so pre-plan callers
that caught ValueError keep working (that compatibility is pinned here).
"""
import numpy as np
import pytest

from repro import knn
from repro.core.errors import EngineConfigError, EpochError, QueryError
from repro.core.partition import PartitionPlan, propose_starts
from repro.core.sharded import ShardLayout, ShardRoutingTable

# ---------------------------------------------------------------------------
# spec parsing (the serve.py --partition surface)
# ---------------------------------------------------------------------------

PARSE_OK = [
    ("shards=4", dict(shards=4, ranges=None, replication=None,
                      policy="round_robin")),
    ("shards=4,replicate=auto:2,ranges=auto",
     dict(shards=4, ranges="auto", replication=("auto", 2),
          policy="round_robin")),
    ("shards=3,ranges=0:100:700",
     dict(shards=3, ranges=(0, 100, 700), replication=None,
          policy="round_robin")),
    ("ranges=0:10:20,policy=least_outstanding",
     dict(shards=3, ranges=(0, 10, 20), replication=None,
          policy="least_outstanding")),
    ("shards=2,replicate=0:3",
     dict(shards=2, ranges=None, replication=((0, 3),),
          policy="round_robin")),
    ("shards=2,ranges=equal",
     dict(shards=2, ranges=None, replication=None, policy="round_robin")),
    ("", dict(shards=None, ranges=None, replication=None,
              policy="round_robin")),
]


@pytest.mark.parametrize("spec,want", PARSE_OK, ids=[s or "<empty>" for s, _ in PARSE_OK])
def test_parse_ok(spec, want):
    plan = PartitionPlan.parse(spec)
    for field, value in want.items():
        assert getattr(plan, field) == value, (spec, field)


PARSE_BAD = [
    "shards",                      # not key=value
    "shard=4",                     # unknown key
    "shards=4,shards=8",           # duplicate key
    "shards=x",                    # not an int
    "shards=0",                    # non-positive
    "ranges=5:10",                 # must start at 0
    "ranges=0:10:10",              # not strictly increasing
    "ranges=0:a",                  # not ints
    "replicate=auto:0",            # auto wants >= 1 extras
    "replicate=3",                 # missing :R
    "replicate=0:-1",              # negative count
    "policy=fastest",              # unknown policy
    "shards=2,ranges=0:10:20",     # shard count vs boundary count mismatch
]


@pytest.mark.parametrize("spec", PARSE_BAD)
def test_parse_bad_is_typed(spec):
    with pytest.raises(EngineConfigError):
        PartitionPlan.parse(spec)


def test_engine_config_error_is_value_error():
    # pre-plan callers caught ValueError; the typed error must stay one
    assert issubclass(EngineConfigError, ValueError)
    with pytest.raises(ValueError):
        PartitionPlan.parse("shards=0")


# ---------------------------------------------------------------------------
# constructor + legacy-shim resolve
# ---------------------------------------------------------------------------

def test_plan_infers_shards_from_ranges():
    plan = PartitionPlan(ranges=(0, 5, 11))
    assert plan.shards == 3
    assert plan.describe()["ranges"] == [0, 5, 11]


def test_plan_replication_dict_and_auto():
    assert PartitionPlan(replication={1: 2, 0: 1}).replication_dict() == {0: 1, 1: 2}
    auto = PartitionPlan(replication=("auto", 2))
    assert auto.replication_dict() is None  # deferred to the serve watcher
    assert auto.auto_replicas() == 2
    assert PartitionPlan().auto_replicas() == 0
    # explicit empty plan = force-drop, distinct from "no opinion"
    assert PartitionPlan.resolve(None, replication={}).replication == ()
    assert PartitionPlan.resolve(None).replication is None


def test_resolve_rejects_plan_plus_legacy_kwargs():
    plan = PartitionPlan(shards=2)
    with pytest.raises(EngineConfigError):
        PartitionPlan.resolve(plan, shards=2)
    with pytest.raises(EngineConfigError):
        PartitionPlan.resolve("shards=2", replication={0: 1})
    # legacy-only and plan-only both fine
    assert PartitionPlan.resolve(None, shards=2).shards == 2
    assert PartitionPlan.resolve("shards=2").shards == 2


@pytest.mark.parametrize("bad", [
    dict(shards=-1), dict(shards=1.5), dict(ranges="fastest"),
    dict(ranges=(1, 2)), dict(ranges=(0, 0)), dict(policy="nope"),
    dict(replication={-1: 1}), dict(replication={0: -2}),
    dict(shards=2, ranges=(0, 1, 2)),
])
def test_plan_constructor_bad_is_typed(bad):
    with pytest.raises(EngineConfigError):
        PartitionPlan(**bad)


# ---------------------------------------------------------------------------
# propose_starts (the histogram-driven splitter)
# ---------------------------------------------------------------------------

def test_propose_starts_balances_weight():
    w = np.zeros(100)
    w[:10] = 9.0   # 90 weight in the first 10 vertices
    w[10:] = 0.1   # 9 in the tail
    starts = propose_starts(w, 4)
    assert starts[0] == 0 and np.all(np.diff(starts) > 0)
    # each range's share close to 1/4 of the total
    bounds = np.append(starts, 100)
    shares = np.add.reduceat(w, starts) / w.sum()
    assert shares.max() < 0.5, (starts, shares)
    assert np.all(bounds[1:] > bounds[:-1])


def test_propose_starts_zero_histogram_is_equal_width():
    assert propose_starts(np.zeros(100), 4).tolist() == [0, 25, 50, 75]
    assert propose_starts(np.zeros(9), 8).tolist() == [0, 2, 3, 4, 5, 6, 7, 8]


def test_propose_starts_degenerate_spike_stays_strictly_increasing():
    w = np.zeros(50)
    w[7] = 1.0  # all the weight on one vertex
    starts = propose_starts(w, 4)
    assert starts[0] == 0 and np.all(np.diff(starts) > 0)
    assert starts[-1] <= 49


@pytest.mark.parametrize("w,s", [
    (np.full(10, -1.0), 2),      # negative weights
    (np.full(10, np.inf), 2),    # non-finite
    (np.ones(10), 11),           # more shards than vertices
    (np.ones(10), 0),            # no shards
])
def test_propose_starts_bad_is_typed(w, s):
    with pytest.raises(EngineConfigError):
        propose_starts(w, s)


def test_propose_starts_length_mismatch():
    with pytest.raises(EngineConfigError):
        propose_starts(np.ones(10), 2, n=12)


# ---------------------------------------------------------------------------
# typed-error audit: routing table + layout misuse
# ---------------------------------------------------------------------------

def test_set_replication_bad_shard_ids_typed():
    rt = ShardRoutingTable(100, 4)
    for bad in ({9: 1}, {-1: 1}, {0: -1}):
        with pytest.raises(EngineConfigError):
            rt.set_replication(bad)
        with pytest.raises(ValueError):  # the compatibility pin
            rt.set_replication(bad)


def test_unknown_route_policy_typed():
    rt = ShardRoutingTable(100, 4)
    with pytest.raises(QueryError):
        rt.route(np.array([0, 50]), policy="fastest")
    with pytest.raises(QueryError):
        rt.assign_slots(np.array([0]), "no_such_policy")


def test_owner_out_of_range_typed():
    rt = ShardRoutingTable(100, 4)
    with pytest.raises(QueryError):
        rt.owner(np.array([200]))
    with pytest.raises(QueryError):
        rt.owner(np.array([-1]))


def test_layout_validation_typed():
    for bad in ((5, 10), (0, 10, 10), (0, 99, 150)):
        with pytest.raises(EngineConfigError):
            ShardLayout.from_starts(100, np.array(bad))
    with pytest.raises(EngineConfigError):
        ShardRoutingTable(100, 2, starts=np.array([0, 10, 20]))  # count mismatch


def test_unretained_epoch_layout_typed():
    rt = ShardRoutingTable(100, 2)
    with pytest.raises(EpochError):
        rt.layout(99)


# ---------------------------------------------------------------------------
# the facade shims construct the same engine as an explicit plan
# ---------------------------------------------------------------------------

def _tiny():
    g = knn.road_network(6, 6, seed=0)
    objects = knn.pick_objects(g.n, 0.2, seed=0)
    bn = knn.build_bngraph(g)
    return g, objects, bn


def test_legacy_shards_kwarg_equals_plan():
    g, objects, bn = _tiny()
    legacy = knn.build_sharded_engine(bn, objects, 4, shards=1)
    planned = knn.build_sharded_engine(bn, objects, 4, plan="shards=1")
    us = np.arange(g.n)
    assert np.array_equal(
        np.asarray(legacy.query_batch(us)[0]),
        np.asarray(planned.query_batch(us)[0]),
    )
    assert legacy.partition_plan() == planned.partition_plan()


def test_facade_rejects_plan_plus_legacy():
    g, objects, bn = _tiny()
    with pytest.raises(EngineConfigError):
        knn.build_sharded_engine(bn, objects, 4, plan="shards=1", shards=1)
    with pytest.raises(EngineConfigError):
        knn.load_engine("unused.npz", plan="shards=1", shards=1)


def test_engine_stats_report_partition_layout():
    g, objects, bn = _tiny()
    eng = knn.build_sharded_engine(bn, objects, 4, plan="shards=1")
    stats = eng.stats()
    assert stats["shard_starts"] == [0]
    assert stats["uneven_ranges"] is False
    assert stats["repartitions"] == 0
    assert eng.partition_plan().describe()["shards"] == 1
