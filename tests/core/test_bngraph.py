"""Property tests for Algorithm 1 (BN-Graph) — Definition 5.3 invariants."""
import heapq

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.graph.generators import random_connected_graph


def dijkstra_all(g, u):
    dist = np.full(g.n, np.inf)
    dist[u] = 0.0
    h = [(0.0, u)]
    while h:
        d, v = heapq.heappop(h)
        if d > dist[v]:
            continue
        nbrs, ws = g.neighbors(v)
        for nb, w in zip(nbrs.tolist(), ws.tolist()):
            if d + w < dist[nb]:
                dist[nb] = d + w
                heapq.heappush(h, (d + w, nb))
    return dist


graph_params = st.tuples(
    st.integers(min_value=4, max_value=40),   # n
    st.integers(min_value=0, max_value=60),   # extra edges
    st.integers(min_value=0, max_value=10_000),  # seed
)


@settings(max_examples=25, deadline=None)
@given(graph_params)
def test_bngraph_invariants(params):
    n, extra, seed = params
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    bn = build_bngraph(g)
    # condition (1): same vertex set
    assert bn.n == g.n
    exact = {u: dijkstra_all(g, u) for u in range(g.n)}
    # condition (2): every G' edge weight equals the true distance in G
    for v in range(g.n):
        for u, w in bn.bns(v):
            assert np.isclose(w, exact[v][u]), (v, u, w, exact[v][u])
    # condition (3) via G' Dijkstra: distances preserved
    adj = bn.adjacency()
    for u in range(0, g.n, max(1, g.n // 5)):
        dist = np.full(g.n, np.inf)
        dist[u] = 0.0
        h = [(0.0, u)]
        while h:
            d, v = heapq.heappop(h)
            if d > dist[v]:
                continue
            for nb, w in adj[v].items():
                if d + w < dist[nb]:
                    dist[nb] = d + w
                    heapq.heappush(h, (d + w, nb))
        assert np.allclose(dist, exact[u]), u


@settings(max_examples=10, deadline=None)
@given(graph_params)
def test_level_schedule_respects_dependencies(params):
    n, extra, seed = params
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    bn = build_bngraph(g)
    for v in range(g.n):
        for u, _ in bn.bns_lower(v):
            assert bn.level_up[u] < bn.level_up[v]
        for u, _ in bn.bns_higher(v):
            assert bn.level_down[u] < bn.level_down[v]


def test_orders_all_build():
    g = random_connected_graph(30, extra_edges=20, seed=3)
    for order in ("mindeg", "degree", "id"):
        bn = build_bngraph(g, order=order)
        assert bn.n == g.n
