"""Replicated hot shards: the shard->replicas fan-out behind the routing table.

The replica contract is the sharded engine's contract one level up: a
replicated engine serves BIT-IDENTICAL results to its unreplicated self (and
so to the scalar engine), no matter which replica slot each query lands on,
which policy chose it, which retained epoch the read pins, or whether a
replica died mid-batch and the engine degraded to the primary path. Most
cases need devices beyond the shard primaries, so the full matrix runs in the
multi-device CI job (8 forced host devices) — which fails if this module is
skipped there (see ci.yml's junit coverage gate).
"""
import numpy as np
import pytest

import jax

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.core.sharded import ShardRoutingTable, ShardedQueryEngine
from repro.graph.generators import pick_objects, road_network

DEVICES = len(jax.devices())
# smallest real fan-out: 2 shards + 1 extra replica device
NEEDS_POOL = pytest.mark.skipif(
    DEVICES < 3, reason="replica fan-out needs devices beyond the shard primaries"
)


def _setup(grid=12, mu=0.15, k=6, seed=0, shards=2):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    plain = knn.QueryEngine.from_index(idx, objects, bn=bn)
    sharded = ShardedQueryEngine.from_index(idx, objects, bn=bn, shards=shards)
    return g, objects, bn, plain, sharded


def _plan(shards: int) -> dict[int, int]:
    """Hot shard 0 replicated over every free device (capped at x3)."""
    return {0: min(3, DEVICES - shards)}


def _boundary_traffic(g, shard_rows, rng):
    return np.concatenate(
        [np.arange(0, g.n, shard_rows), np.arange(shard_rows - 1, g.n, shard_rows),
         rng.integers(0, g.n, 128), [-3, -1, g.n, g.n + 7]]
    ).astype(np.int32)


@NEEDS_POOL
@pytest.mark.parametrize("policy", ["round_robin", "least_outstanding"])
def test_replicated_serving_bit_identical(policy):
    """Boundary-heavy traffic (incl. out-of-range ids and mixed ks) through
    the replica fan-out == the unreplicated engine == the scalar engine,
    under both routing policies."""
    shards = min(4, DEVICES - 1)
    g, objects, bn, plain, sharded = _setup(shards=shards)
    sharded.set_replication(_plan(shards), policy=policy)
    rng = np.random.default_rng(1)
    for us in (_boundary_traffic(g, sharded.shard_rows, rng),
               rng.integers(0, g.n, size=257).astype(np.int32)):
        pi, pd = plain.query_batch(us)
        si, sd = sharded.query_batch(us)
        assert np.array_equal(np.asarray(pi), np.asarray(si))
        assert np.array_equal(np.asarray(pd), np.asarray(sd))
        ks = rng.integers(1, plain.k + 1, size=len(us)).astype(np.int32)
        pi, pd = plain.query_batch(us, ks)
        si, sd = sharded.query_batch(us, ks)
        assert np.array_equal(np.asarray(pi), np.asarray(si))
        assert np.array_equal(np.asarray(pd), np.asarray(sd))
    assert sharded.stats()["replica_batches"] > 0
    assert sharded.stats()["replica_errors"] == 0


@NEEDS_POOL
def test_replica_buffers_byte_identical_every_epoch():
    """Every retained epoch's replica slots hold byte-for-byte the primary
    shard's block — publish puts replicas and primaries through the same
    atomic epoch step, so a replica can never serve a different epoch."""
    shards = min(4, DEVICES - 1)
    g, objects, bn, plain, sharded = _setup(shards=shards)
    sharded.keep_epochs = 3
    sharded.set_replication(_plan(shards))
    mset = set(int(o) for o in objects)
    for seed in (3, 4):
        knn.stage_random_updates(sharded, mset, rng=seed, count=4)
        sharded.flush_updates()
    epochs = sharded.retained_epochs()
    assert len(epochs) >= 2
    for epoch in epochs:
        primaries = {}
        replicas = []
        for slot, (shard, _dev, ids, dists) in sharded.routing.replica_buffers(epoch).items():
            if slot < sharded.num_shards:
                primaries[shard] = (ids, dists)
            else:
                replicas.append((shard, ids, dists))
        assert replicas, "plan installed but no replica slots published"
        for shard, ids, dists in replicas:
            pi, pd = primaries[shard]
            assert np.array_equal(np.asarray(ids), np.asarray(pi))
            assert np.array_equal(np.asarray(dists), np.asarray(pd))


@NEEDS_POOL
def test_pinned_epoch_replica_reads_after_flush():
    """A query pinned to an old epoch reads the old replica buffers even
    after later flushes republished the serving layout."""
    shards = min(4, DEVICES - 1)
    g, objects, bn, plain, sharded = _setup(shards=shards)
    sharded.keep_epochs = 2
    sharded.set_replication(_plan(shards))
    rng = np.random.default_rng(2)
    us = _boundary_traffic(g, sharded.shard_rows, rng)
    e0 = sharded.epoch
    i0, d0 = sharded.query_batch(us)
    mset = set(int(o) for o in objects)
    knn.stage_random_updates(sharded, mset, rng=7, count=6)
    sharded.flush_updates()
    i_pin, d_pin = sharded.query_batch(us, epoch=e0)
    assert np.array_equal(np.asarray(i_pin), np.asarray(i0))
    assert np.array_equal(np.asarray(d_pin), np.asarray(d0))
    i1, _ = sharded.query_batch(us)  # the new epoch serves updated tables
    assert not np.array_equal(np.asarray(i1), np.asarray(i0))


@NEEDS_POOL
def test_replica_failure_degrades_to_primary_exactly():
    """A replica fault mid-batch falls back to the primary-only path with
    bit-identical results and one counted error; the next batch fans out
    through the replicas again."""
    shards = min(4, DEVICES - 1)
    g, objects, bn, plain, sharded = _setup(shards=shards)
    sharded.set_replication(_plan(shards))
    rng = np.random.default_rng(3)
    us = _boundary_traffic(g, sharded.shard_rows, rng)

    def boom(engine):
        engine.replica_fault_hook = None  # fail exactly one batch
        raise RuntimeError("simulated replica loss")

    sharded.replica_fault_hook = boom
    si, sd = sharded.query_batch(us)
    pi, pd = plain.query_batch(us)
    assert np.array_equal(np.asarray(pi), np.asarray(si))
    assert np.array_equal(np.asarray(pd), np.asarray(sd))
    stats = sharded.stats()
    assert stats["replica_errors"] == 1
    assert "simulated replica loss" in sharded._rstats["last_replica_error"]

    before = stats["replica_batches"]
    si2, _ = sharded.query_batch(us)
    assert np.array_equal(np.asarray(pi), np.asarray(si2))
    assert sharded.stats()["replica_batches"] == before + 1  # fan-out restored


@NEEDS_POOL
def test_reshard_on_load_replication_plans(tmp_path):
    """Save/load across replica plans: a saved plan re-applies at the same
    shard count, is dropped by a reshard (plans are keyed by shard id), is
    force-dropped by ``replication={}``, and is overridden by a new plan."""
    shards = min(4, DEVICES - 1)
    g, objects, bn, plain, sharded = _setup(shards=shards)
    plan = _plan(shards)
    sharded.set_replication(plan)
    path = str(tmp_path / "rep.npz")
    sharded.save(path)
    rng = np.random.default_rng(4)
    us = rng.integers(0, g.n, size=129).astype(np.int32)
    want_i, want_d = plain.query_batch(us)

    same = ShardedQueryEngine.load(path, bn=bn, shards=shards)
    assert same.routing.replication == plan
    assert same.stats()["replica_batches"] == 0
    i, d = same.query_batch(us)
    assert np.array_equal(np.asarray(i), np.asarray(want_i))
    assert np.array_equal(np.asarray(d), np.asarray(want_d))
    assert same.stats()["replica_batches"] == 1  # served through the fan-out

    other = max(1, shards // 2)
    resharded = ShardedQueryEngine.load(path, bn=bn, shards=other)
    assert resharded.routing.replication == {}  # reshard invalidates the plan
    i, _ = resharded.query_batch(us)
    assert np.array_equal(np.asarray(i), np.asarray(want_i))

    dropped = ShardedQueryEngine.load(path, bn=bn, shards=shards, replication={})
    assert dropped.routing.replication == {}

    override = {0: 1}
    overridden = ShardedQueryEngine.load(
        path, bn=bn, shards=shards, replication=override
    )
    assert overridden.routing.replication == override
    i, _ = overridden.query_batch(us)
    assert np.array_equal(np.asarray(i), np.asarray(want_i))


def test_routing_table_owner_validates_range():
    """``owner`` raises a typed QueryError for ids outside [0, n] instead of
    silently clipping them into the last shard."""
    rt = ShardRoutingTable(100, 4)
    own = rt.owner(np.array([0, 99, 100]))  # n itself is the dummy-row address
    assert own.shape == (3,)
    with pytest.raises(knn.QueryError):
        rt.owner(np.array([-1]))
    with pytest.raises(knn.QueryError):
        rt.owner(np.array([101]))


def test_routing_table_policies():
    """Slot assignment spreads a hot shard's queries across its replica set
    under both policies; unknown policies and bad plans raise typed errors."""
    rt = ShardRoutingTable(100, 4)
    rt.set_replication({1: 2})
    assert rt.num_slots == 6
    assert list(rt.slot_shard) == [0, 1, 2, 3, 1, 1]

    vs = np.full(30, 30, dtype=np.int64)  # 30 queries, all owned by shard 1
    own, slots = rt.route(vs, policy="round_robin")
    assert np.all(own == 1)
    counts = {s: int(np.sum(slots == s)) for s in (1, 4, 5)}
    assert sum(counts.values()) == 30
    assert all(c == 10 for c in counts.values())  # even round-robin split

    rt.outstanding[:] = 0
    rt.outstanding[4] = 25  # slot 4 is backed up: water-fill avoids it
    own, slots = rt.route(vs, policy="least_outstanding")
    assert np.all(np.isin(slots, (1, 4, 5)))
    assert int(np.sum(slots == 4)) < int(np.sum(slots == 1))

    with pytest.raises(knn.QueryError):
        rt.route(vs, policy="fastest_guess")
    with pytest.raises(ValueError):
        rt.set_replication({9: 1})  # unknown shard id
    with pytest.raises(ValueError):
        rt.set_replication({0: -1})  # negative replica counts are nonsense
    assert list(rt.set_replication({0: 0})) == [0, 1, 2, 3]  # zero extras == no plan
