"""Algorithm 2 / Algorithm 3 / JAX fused-sweep construction vs Dijkstra oracle."""
import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import construct_jax
from repro.core.bngraph import build_bngraph
from repro.core.construct_jax import (
    build_knn_index_jax,
    object_extras,
    prepare_sweep,
    run_sweep,
)
from repro.core.index import indices_equivalent
from repro.core.reference import dijkstra_cons, knn_index_cons, knn_index_cons_plus
from repro.graph.generators import pick_objects, random_connected_graph, road_network

params = st.tuples(
    st.integers(min_value=5, max_value=45),
    st.integers(min_value=0, max_value=60),
    st.integers(min_value=0, max_value=10_000),
    st.floats(min_value=0.2, max_value=1.0),
    st.integers(min_value=1, max_value=8),
)


@settings(max_examples=20, deadline=None)
@given(params)
def test_alg2_alg3_match_oracle(p):
    n, extra, seed, mu, k = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, mu, seed=seed)
    bn = build_bngraph(g)
    oracle = dijkstra_cons(g, objects, k)
    assert indices_equivalent(oracle, knn_index_cons(bn, objects, k))
    assert indices_equivalent(oracle, knn_index_cons_plus(bn, objects, k))


@settings(max_examples=8, deadline=None)
@given(params)
def test_jax_construction_matches_reference(p):
    n, extra, seed, mu, k = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, mu, seed=seed)
    bn = build_bngraph(g)
    ref = knn_index_cons_plus(bn, objects, k)
    jx = build_knn_index_jax(bn, objects, k, use_pallas=False)
    assert indices_equivalent(ref, jx)


def test_jax_construction_pallas_road():
    g = road_network(10, 10, seed=5)
    objects = pick_objects(g.n, 0.2, seed=5)
    bn = build_bngraph(g)
    ref = knn_index_cons_plus(bn, objects, 6)
    jx = build_knn_index_jax(bn, objects, 6, use_pallas=True)
    assert indices_equivalent(ref, jx)


def test_sweep_plan_layout_and_occupancy():
    g = road_network(12, 12, seed=1)
    bn = build_bngraph(g)
    for direction in ("up", "down"):
        plan = prepare_sweep(bn, direction)
        assert 0 < plan.occupancy <= 1
        assert 0 < plan.occupancy_levelwise <= 1
        assert sum(plan.level_sizes) == g.n
        # every chunk names a valid in-bucket row range
        cb = np.asarray(plan.chunk_bucket)
        co = np.asarray(plan.chunk_off)
        assert plan.num_chunks == cb.shape[0] == co.shape[0]
        for b, off in zip(cb.tolist(), co.tolist()):
            bucket = plan.buckets[b]
            assert off + bucket.chunk <= bucket.verts.shape[0]
        # padded rows carry the dummy vertex id n, real rows each vertex once
        all_verts = np.concatenate([np.asarray(b.verts) for b in plan.buckets])
        real = all_verts[all_verts < g.n]
        assert sorted(real.tolist()) == list(range(g.n))


def test_run_sweep_zero_host_transfers():
    """The schedule is uploaded once; the sweep itself must not touch host."""
    g = road_network(9, 9, seed=2)
    objects = pick_objects(g.n, 0.3, seed=2)
    bn = build_bngraph(g)
    k = 5
    plan_up = prepare_sweep(bn, "up")
    plan_down = prepare_sweep(bn, "down")
    ex_ids, ex_d = object_extras(g.n, objects, k)
    with jax.transfer_guard("disallow"):
        vkl_ids, vkl_d = run_sweep(plan_up, ex_ids, ex_d, k, use_pallas=False)
        vk_ids, vk_d = run_sweep(plan_down, vkl_ids, vkl_d, k, use_pallas=False)
        jax.block_until_ready((vk_ids, vk_d))
    ref = knn_index_cons_plus(bn, objects, k)
    ids = np.asarray(vk_ids[: g.n])
    dists = np.where(ids >= 0, np.asarray(vk_d[: g.n], np.float64), np.inf)
    from repro.core.index import KNNIndex

    assert indices_equivalent(ref, KNNIndex(ids=ids, dists=dists, k=k))


def test_sweep_compilations_bounded_by_buckets():
    """A full build compiles at most one program per sweep direction."""
    g = road_network(11, 13, seed=7)
    objects = pick_objects(g.n, 0.2, seed=7)
    bn = build_bngraph(g)
    before = construct_jax.sweep_compile_count()
    if before < 0:
        import pytest

        pytest.skip("jit cache introspection unavailable in this jax version")
    build_knn_index_jax(bn, objects, 4, use_pallas=False)
    first = construct_jax.sweep_compile_count() - before
    n_buckets = len(prepare_sweep(bn, "up").buckets) + len(
        prepare_sweep(bn, "down").buckets
    )
    assert first <= min(2, n_buckets)
    # a rebuild on the same graph shape reuses every program
    build_knn_index_jax(bn, objects, 4, use_pallas=False)
    assert construct_jax.sweep_compile_count() - before == first
