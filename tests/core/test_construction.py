"""Algorithm 2 / Algorithm 3 / JAX level-sync construction vs Dijkstra oracle."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.core.construct_jax import build_knn_index_jax, prepare_sweep
from repro.core.index import indices_equivalent
from repro.core.reference import dijkstra_cons, knn_index_cons, knn_index_cons_plus
from repro.graph.generators import pick_objects, random_connected_graph, road_network

params = st.tuples(
    st.integers(min_value=5, max_value=45),
    st.integers(min_value=0, max_value=60),
    st.integers(min_value=0, max_value=10_000),
    st.floats(min_value=0.2, max_value=1.0),
    st.integers(min_value=1, max_value=8),
)


@settings(max_examples=20, deadline=None)
@given(params)
def test_alg2_alg3_match_oracle(p):
    n, extra, seed, mu, k = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, mu, seed=seed)
    bn = build_bngraph(g)
    oracle = dijkstra_cons(g, objects, k)
    assert indices_equivalent(oracle, knn_index_cons(bn, objects, k))
    assert indices_equivalent(oracle, knn_index_cons_plus(bn, objects, k))


@settings(max_examples=8, deadline=None)
@given(params)
def test_jax_construction_matches_reference(p):
    n, extra, seed, mu, k = p
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = pick_objects(n, mu, seed=seed)
    bn = build_bngraph(g)
    ref = knn_index_cons_plus(bn, objects, k)
    jx = build_knn_index_jax(bn, objects, k, use_pallas=False)
    assert indices_equivalent(ref, jx)


def test_jax_construction_pallas_road():
    g = road_network(14, 14, seed=5)
    objects = pick_objects(g.n, 0.2, seed=5)
    bn = build_bngraph(g)
    ref = knn_index_cons_plus(bn, objects, 6)
    jx = build_knn_index_jax(bn, objects, 6, use_pallas=True)
    assert indices_equivalent(ref, jx)


def test_sweep_plan_occupancy_reported():
    g = road_network(12, 12, seed=1)
    bn = build_bngraph(g)
    plan = prepare_sweep(bn, "up")
    assert 0 < plan.occupancy <= 1
    assert sum(lb.size for lb in plan.levels) == g.n
