"""Update frontiers vs a brute-force multi-source Dijkstra oracle.

``insert_affected_set`` (the checkIns frontier, shared by the host oracle and
the engine's batched flush) and the delete frontier (the oracle's checkDel
search and the engine's ``ops.rows_containing`` device scan) were previously
tested only transitively, through whole-index equivalence after updates.
These properties pin them down directly: on random road networks with
*continuous* edge weights (ties have probability zero, so every set below is
exact, not a superset), the brute-force oracle recomputes all object->vertex
distances with one Dijkstra per object per update and derives the ground
truth:

* insert u:  affected == {w : dist(w, u) < kth(w)} | {u}, with exact
  distances, and it covers every row the brute-force index changes;
* delete u:  the checkDel frontier == the rows naming u == the rows the
  brute-force index changes == the engine's ``rows_containing`` scan.
"""
import heapq

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.core.index import PAD_ID, KNNIndex, index_from_lists
from repro.core.updates import _affected_set, insert_affected_set
from repro.graph.csr import Graph
from repro.graph.generators import pick_objects, road_network
from repro.kernels import ops


def _sssp(g: Graph, src: int) -> np.ndarray:
    """Plain single-source Dijkstra over the road network; (n,) distances."""
    dist = np.full(g.n, np.inf)
    dist[src] = 0.0
    heap = [(0.0, src)]
    while heap:
        d, v = heapq.heappop(heap)
        if d > dist[v]:
            continue
        nbrs, ws = g.neighbors(v)
        for nb, w in zip(nbrs.tolist(), ws.tolist()):
            nd = d + w
            if nd < dist[nb]:
                dist[nb] = nd
                heapq.heappush(heap, (nd, nb))
    return dist


def _brute_knn(g: Graph, objects: np.ndarray, k: int) -> KNNIndex:
    """Ground-truth index: one Dijkstra per object, top-k per vertex."""
    dmat = np.stack([_sssp(g, int(o)) for o in objects], axis=1)  # (n, |M|)
    rows = []
    for v in range(g.n):
        order = np.lexsort((objects, dmat[v]))[:k]
        rows.append([(int(objects[j]), float(dmat[v, j])) for j in order
                     if np.isfinite(dmat[v, j])])
    return index_from_lists(g.n, k, rows)


def _kth(index: KNNIndex, v: int) -> float:
    return np.inf if index.ids[v, -1] == PAD_ID else float(index.dists[v, -1])


def _changed_rows(a: KNNIndex, b: KNNIndex) -> set:
    return {
        v
        for v in range(a.n)
        if not (
            np.array_equal(a.ids[v], b.ids[v])
            and np.allclose(
                np.where(np.isinf(a.dists[v]), -1, a.dists[v]),
                np.where(np.isinf(b.dists[v]), -1, b.dists[v]),
            )
        )
    }


params = st.tuples(
    st.integers(min_value=3, max_value=6),   # grid nx
    st.integers(min_value=3, max_value=6),   # grid ny
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=4),   # k
)


def _setup(nx, ny, seed, k):
    # continuous weights: distance ties are measure-zero, every assertion
    # below is an exact set equality instead of a tie-tolerant inclusion
    g = road_network(nx, ny, seed=seed, integer_weights=False)
    objects = pick_objects(g.n, 0.35, seed=seed)
    bn = build_bngraph(g)
    return g, objects, bn, _brute_knn(g, objects, k)


@settings(max_examples=12, deadline=None)
@given(params)
def test_insert_frontier_matches_brute_force(p):
    nx, ny, seed, k = p
    g, objects, bn, idx = _setup(nx, ny, seed, k)
    outside = np.setdiff1d(np.arange(g.n), objects)
    if outside.size == 0:
        return
    u = int(outside[np.random.default_rng(seed).integers(0, outside.size)])

    dist_u = _sssp(g, u)
    affected = insert_affected_set(bn, lambda v: _kth(idx, v), u)

    expected = {w for w in range(g.n) if dist_u[w] < _kth(idx, w)} | {u}
    assert set(affected) == expected
    for w, d in affected.items():  # BN-Graph preserves exact distances
        assert np.isclose(d, dist_u[w])

    # every row the ground-truth index changes is in the frontier
    after = _brute_knn(g, np.sort(np.append(objects, u)), k)
    assert _changed_rows(idx, after) <= set(affected)


def _relax_to_fixpoint(bn, kth: np.ndarray, srcs: np.ndarray):
    """Drive ``ops.frontier_relax`` rounds to their fixpoint (the test-side
    twin of ``EngineCore._insert_frontier``, without bucketing): returns the
    converged (n+1, B) distance matrix. Runs in float64 when JAX x64 is on —
    then every distance must EQUAL the host oracle's bit for bit — and in
    float32 otherwise (the engine's serving dtype)."""
    dtype = np.float64 if jax.config.jax_enable_x64 else np.float32
    packed = bn.bns_packed()
    n, b = bn.n, len(srcs)
    kth_j = jnp.asarray(np.append(kth, np.inf).astype(dtype))
    src_j = jnp.asarray(srcs.astype(np.int32))
    dist0 = np.full((n + 1, b), np.inf, dtype)
    dist0[srcs, np.arange(b)] = 0.0
    dist = jnp.asarray(dist0)
    active = np.unique(srcs)
    for _ in range(300):
        recv = np.unique(packed.ids[active])
        recv = recv[recv >= 0].astype(np.int32)
        rows = jnp.asarray(recv)
        new = ops.frontier_relax(
            jnp.asarray(packed.ids[recv]), rows,
            jnp.asarray(packed.w[recv].astype(dtype)),
            dist, kth_j, src_j, use_pallas=False,
        )
        changed = np.asarray(jnp.any(new[rows] < dist[rows], axis=1))
        dist = new
        active = recv[changed]
        if not active.size:
            return np.asarray(dist)
    raise AssertionError("frontier relaxation did not converge")


@settings(max_examples=12, deadline=None)
@given(params)
def test_frontier_relax_fixpoint_matches_insert_affected_set(p):
    """ops.frontier_relax rounds, run for a BATCH of inserted objects at
    once, land on exactly the per-source checkIns affected sets of the host
    oracle — same sets, same distances (bit-equal under x64, float32-rounded
    otherwise). Distances accumulate per column independently, so the batch
    dimension must not couple sources."""
    import dataclasses

    nx, ny, seed, k = p
    g, objects, bn, idx = _setup(nx, ny, seed, k)
    outside = np.setdiff1d(np.arange(g.n), objects)
    if outside.size < 2:
        return
    rng = np.random.default_rng(seed)
    b = min(4, outside.size)
    srcs = np.sort(rng.choice(outside, size=b, replace=False))

    # pre-round the BNS weights and the pruning column to float32 so the
    # oracle's host sums and the device relaxation see identical inputs
    # (the serving tables and packed adjacency are float32; under x64 the
    # sums themselves are then bit-equal too)
    bn = dataclasses.replace(
        bn,
        lo_w=bn.lo_w.astype(np.float32).astype(np.float64),
        hi_w=bn.hi_w.astype(np.float32).astype(np.float64),
    )
    kth = np.array([_kth(idx, v) for v in range(g.n)])
    kth = kth.astype(np.float32).astype(np.float64)

    dist = _relax_to_fixpoint(bn, kth, srcs)
    exact = jax.config.jax_enable_x64
    for i, u in enumerate(srcs.tolist()):
        want = insert_affected_set(bn, lambda v: float(kth[v]), u)
        got = {
            v for v in range(g.n)
            if dist[v, i] < kth[v] or v == u
        }
        assert got == set(want)
        for v, d in want.items():
            if exact:
                assert float(dist[v, i]) == d
            else:
                assert np.isclose(float(dist[v, i]), d, rtol=2e-6, atol=0)


@settings(max_examples=12, deadline=None)
@given(params)
def test_delete_frontier_matches_brute_force(p):
    nx, ny, seed, k = p
    g, objects, bn, idx = _setup(nx, ny, seed, k)
    u = int(objects[np.random.default_rng(seed).integers(0, len(objects))])

    naming_u = {w for w in range(g.n) if u in idx.ids[w]}

    # the oracle's checkDel frontier explores exactly the rows naming u
    affected = _affected_set(bn, idx, u, for_delete=True)
    assert set(affected) == naming_u
    dist_u = _sssp(g, u)
    for w, d in affected.items():
        assert np.isclose(d, dist_u[w])

    # the engine's device scan finds the same delete frontier
    tables = np.concatenate([idx.ids, np.full((1, k), PAD_ID, np.int32)])
    hit = np.asarray(ops.rows_containing(tables, np.array([u], np.int32)))
    assert set(np.flatnonzero(hit).tolist()) == naming_u

    # and the ground-truth index changes exactly on those rows
    after = _brute_knn(g, objects[objects != u], k)
    assert _changed_rows(idx, after) == naming_u
