"""Algorithms 4/5 (object insert/delete/move) vs rebuild-from-scratch.

The property covers both update paths: the scalar host oracle
(insert_object/delete_object/move_object, one op at a time) AND the
QueryEngine's batched staged equivalents (stage_* + flush_updates at random
points, moves included in the interleaving) must land indices_equivalent to
a fresh knn_index_cons_plus rebuild on the final object set — and therefore
to each other.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.core.engine import QueryEngine
from repro.core.index import indices_equivalent
from repro.core.reference import knn_index_cons_plus
from repro.core.updates import delete_object, insert_object, move_object
from repro.graph.generators import pick_objects, random_connected_graph, road_network

params = st.tuples(
    st.integers(min_value=8, max_value=40),
    st.integers(min_value=0, max_value=40),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=5),
    st.integers(min_value=1, max_value=12),  # number of updates
)


@settings(max_examples=15, deadline=None)
@given(params)
def test_mixed_updates_match_rebuild(p):
    n, extra, seed, k, n_updates = p
    rng = np.random.default_rng(seed)
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = set(pick_objects(n, 0.5, seed=seed).tolist())
    if len(objects) <= k + n_updates:  # keep |M| > k through deletions
        objects |= set(range(min(n, k + n_updates + 2)))
    bn = build_bngraph(g)
    obj0 = np.array(sorted(objects))
    idx = knn_index_cons_plus(bn, obj0, k)
    engine = QueryEngine.from_index(idx, obj0, bn=bn)
    for _ in range(n_updates):
        u = int(rng.integers(0, n))
        r = rng.random()
        outside = [v for v in range(n) if v not in objects]
        if r < 0.35 and objects and outside:
            # a move: a present object relocates to an absent vertex
            src = int(rng.choice(sorted(objects)))
            dst = int(rng.choice(outside))
            move_object(bn, idx, src, dst)
            engine.stage_move(src, dst)
            objects.discard(src)
            objects.add(dst)
        elif u in objects:
            if len(objects) <= k + 1:
                continue
            delete_object(bn, idx, u)
            engine.stage_delete(u)
            objects.discard(u)
        else:
            insert_object(bn, idx, u)
            engine.stage_insert(u)
            objects.add(u)
        if rng.random() < 0.3:  # flush at random interleaving points
            engine.flush_updates()
    engine.flush_updates()
    fresh = knn_index_cons_plus(bn, np.array(sorted(objects)), k)
    assert indices_equivalent(fresh, idx)
    assert indices_equivalent(fresh, engine.to_index())
    assert indices_equivalent(idx, engine.to_index())


def test_insert_then_delete_roundtrip():
    g = road_network(10, 10, seed=2)
    objects = pick_objects(g.n, 0.3, seed=2)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 4)
    before = idx.copy()
    outside = [v for v in range(g.n) if v not in set(objects.tolist())][0]
    insert_object(bn, idx, outside)
    delete_object(bn, idx, outside)
    assert indices_equivalent(before, idx)
    assert np.array_equal(before.ids, idx.ids)


def test_move_there_and_back_roundtrip():
    g = road_network(10, 10, seed=3)
    objects = pick_objects(g.n, 0.3, seed=3)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 4)
    before = idx.copy()
    src = int(objects[0])
    dst = [v for v in range(g.n) if v not in set(objects.tolist())][0]
    move_object(bn, idx, src, dst)
    fresh = knn_index_cons_plus(
        bn, np.array(sorted(set(objects.tolist()) - {src} | {dst})), 4
    )
    assert indices_equivalent(fresh, idx)
    move_object(bn, idx, dst, src)
    assert indices_equivalent(before, idx)


def test_move_to_same_vertex_raises():
    g = road_network(6, 6, seed=0)
    objects = pick_objects(g.n, 0.3, seed=0)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 3)
    with pytest.raises(ValueError):
        move_object(bn, idx, int(objects[0]), int(objects[0]))
