"""Algorithms 4/5 (object insert/delete/move) vs rebuild-from-scratch.

The property covers every update path, four ways: the scalar host oracle
(insert_object/delete_object/move_object, one op at a time), the
QueryEngine's batched staged equivalents (stage_* + flush_updates at random
points, moves included in the interleaving) AND the multi-device
ShardedQueryEngine replaying the identical staged script must all land
indices_equivalent to a fresh knn_index_cons_plus rebuild on the final
object set — and therefore to each other. The two engines are additionally
held to *exact* table equivalence after every flush (the sharded flush is
the same math, only partitioned by vertex owner), and a third engine replay
runs the flush pipeline with ``frontier = "host"`` — pinning the batched
device checkIns frontier (``ops.frontier_relax`` rounds) byte-for-byte
against the per-object ``insert_affected_set`` pipeline on every flush.
When the device pool allows two shards, a sixth replay runs the sharded
engine under an uneven ``PartitionPlan(ranges=...)`` boundary layout and is
held to the same exact table equality — partition boundaries may never
change results — and a seventh runs the sharded engine with
``halo = "host"``, pinning the collective all_gather halo exchange (the
multi-shard default) byte-for-byte against the routed host-fetch halo on
every flush.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bngraph import build_bngraph
from repro.core.engine import QueryEngine
from repro.core.index import indices_equivalent
from repro.core.partition import PartitionPlan
from repro.core.reference import knn_index_cons_plus
from repro.core.sharded import ShardedQueryEngine
from repro.core.updates import delete_object, insert_object, move_object
from repro.graph.generators import pick_objects, random_connected_graph, road_network

params = st.tuples(
    st.integers(min_value=8, max_value=40),
    st.integers(min_value=0, max_value=40),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=5),
    st.integers(min_value=1, max_value=12),  # number of updates
)


@settings(max_examples=15, deadline=None)
@given(params)
def test_mixed_updates_match_rebuild(p):
    n, extra, seed, k, n_updates = p
    rng = np.random.default_rng(seed)
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    objects = set(pick_objects(n, 0.5, seed=seed).tolist())
    if len(objects) <= k + n_updates:  # keep |M| > k through deletions
        objects |= set(range(min(n, k + n_updates + 2)))
    bn = build_bngraph(g)
    obj0 = np.array(sorted(objects))
    idx = knn_index_cons_plus(bn, obj0, k)
    engine = QueryEngine.from_index(idx, obj0, bn=bn)
    # the fourth party: the same staged script through the sharded engine
    # (multi-shard when the device pool allows it, see the CI device matrix)
    shards = min(2, len(jax.devices()), n)
    sharded = ShardedQueryEngine.from_index(idx, obj0, bn=bn, shards=shards)
    # the fifth party: the host-frontier pipeline (per-object
    # insert_affected_set) — must stay byte-identical to the device frontier
    hostf = QueryEngine.from_index(idx, obj0, bn=bn)
    hostf.frontier = "host"
    # the sixth party: the sharded engine under UNEVEN range boundaries (a
    # deliberately lopsided split) — layout may never leak into results
    engines = [engine, sharded, hostf]
    if shards == 2:
        uneven = ShardedQueryEngine.from_index(
            idx, obj0, bn=bn, plan=PartitionPlan(ranges=(0, max(1, n // 3)))
        )
        engines.append(uneven)
        # the seventh party: the sharded engine with the routed host halo —
        # the collective exchange (the multi-shard default above) and the
        # host fetch path must stay byte-identical at every flush
        hosth = ShardedQueryEngine.from_index(idx, obj0, bn=bn, shards=shards)
        hosth.halo = "host"
        engines.append(hosth)
    for _ in range(n_updates):
        u = int(rng.integers(0, n))
        r = rng.random()
        outside = [v for v in range(n) if v not in objects]
        if r < 0.35 and objects and outside:
            # a move: a present object relocates to an absent vertex
            src = int(rng.choice(sorted(objects)))
            dst = int(rng.choice(outside))
            move_object(bn, idx, src, dst)
            for e in engines:
                e.stage_move(src, dst)
            objects.discard(src)
            objects.add(dst)
        elif u in objects:
            if len(objects) <= k + 1:
                continue
            delete_object(bn, idx, u)
            for e in engines:
                e.stage_delete(u)
            objects.discard(u)
        else:
            insert_object(bn, idx, u)
            for e in engines:
                e.stage_insert(u)
            objects.add(u)
        if rng.random() < 0.3:  # flush at random interleaving points
            assert engine.flush_updates() == sharded.flush_updates()
            for e in engines[2:]:
                e.flush_updates()
            a = engine.to_index()
            for e in engines[1:]:  # exact tables, not just equivalent:
                b = e.to_index()  # sharded == scalar, host == device
                assert np.array_equal(a.ids, b.ids)  # frontier, uneven ==
                assert np.array_equal(a.dists, b.dists)  # equal-width
    for e in engines:
        e.flush_updates()
    fresh = knn_index_cons_plus(bn, np.array(sorted(objects)), k)
    assert indices_equivalent(fresh, idx)
    assert indices_equivalent(fresh, engine.to_index())
    assert indices_equivalent(idx, engine.to_index())
    assert indices_equivalent(fresh, sharded.to_index())
    a = engine.to_index()
    for e in engines[1:]:
        b = e.to_index()
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)


def test_insert_then_delete_roundtrip():
    g = road_network(10, 10, seed=2)
    objects = pick_objects(g.n, 0.3, seed=2)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 4)
    before = idx.copy()
    outside = [v for v in range(g.n) if v not in set(objects.tolist())][0]
    insert_object(bn, idx, outside)
    delete_object(bn, idx, outside)
    assert indices_equivalent(before, idx)
    assert np.array_equal(before.ids, idx.ids)


def test_move_there_and_back_roundtrip():
    g = road_network(10, 10, seed=3)
    objects = pick_objects(g.n, 0.3, seed=3)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 4)
    before = idx.copy()
    src = int(objects[0])
    dst = [v for v in range(g.n) if v not in set(objects.tolist())][0]
    move_object(bn, idx, src, dst)
    fresh = knn_index_cons_plus(
        bn, np.array(sorted(set(objects.tolist()) - {src} | {dst})), 4
    )
    assert indices_equivalent(fresh, idx)
    move_object(bn, idx, dst, src)
    assert indices_equivalent(before, idx)


def test_move_to_same_vertex_raises():
    g = road_network(6, 6, seed=0)
    objects = pick_objects(g.n, 0.3, seed=0)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, 3)
    with pytest.raises(ValueError):
        move_object(bn, idx, int(objects[0]), int(objects[0]))
