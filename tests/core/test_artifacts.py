"""Artifact + journal robustness: corruption raises typed errors, torn
journal tails recover cleanly (ISSUE 6 satellite).

Table-driven over the corruption modes an on-disk index can meet:
truncated npz, content-checksum mismatch, schema-version skew — each must
raise ``ArtifactError`` (never silently serve garbage tables) — plus the
journal's torn-tail recovery and the error-taxonomy type contracts.
"""
import json
import os

import numpy as np
import pytest

from repro import knn
from repro.core.engine import _FORMAT_VERSION
from repro.graph.generators import pick_objects, road_network


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    g = road_network(8, 8, seed=0)
    objects = pick_objects(g.n, 0.2, seed=0)
    bn = knn.build_bngraph(g)
    eng = knn.build_engine(bn, objects, k=4)
    art = str(tmp_path_factory.mktemp("artifacts") / "idx.npz")
    eng.save(art)
    return g, bn, objects, eng, art


def _rewrite(src, dst, mutate):
    """Round-trip the npz through a mutation of (arrays, meta)."""
    with np.load(src) as z:
        data = {f: z[f] for f in z.files}
    meta = json.loads(bytes(data["meta"]))
    mutate(data, meta)
    data["meta"] = np.bytes_(json.dumps(meta).encode())
    np.savez_compressed(dst, **data)


def _truncate(src, dst):
    raw = open(src, "rb").read()
    with open(dst, "wb") as f:
        f.write(raw[: len(raw) // 2])


def _flip_table_bit(data, meta):
    # tables change, stored checksum doesn't -> mismatch
    data["dists"] = data["dists"] + np.float32(1.0)


def _future_version(data, meta):
    meta["version"] = _FORMAT_VERSION + 7


CORRUPTIONS = [
    ("truncated", lambda s, d: _truncate(s, d), "truncated or corrupt"),
    ("checksum", lambda s, d: _rewrite(s, d, _flip_table_bit), "checksum mismatch"),
    ("version-skew", lambda s, d: _rewrite(s, d, _future_version), "schema version"),
]


@pytest.mark.parametrize("name,corrupt,msg", CORRUPTIONS, ids=[c[0] for c in CORRUPTIONS])
def test_corrupt_artifact_raises_typed_error(built, tmp_path, name, corrupt, msg):
    _, bn, _, _, art = built
    bad = str(tmp_path / f"{name}.npz")
    corrupt(art, bad)
    with pytest.raises(knn.ArtifactError, match=msg):
        knn.load_engine(bad, bn=bn)
    # the taxonomy keeps the pre-taxonomy builtin contract too
    with pytest.raises(RuntimeError):
        knn.load_engine(bad, bn=bn)


def test_unversioned_legacy_artifact_still_loads(built, tmp_path):
    """v1/v2 artifacts carry no checksum: they load unverified rather than
    being rejected (no flag day for existing saved indexes)."""
    g, bn, _, eng, art = built
    legacy = str(tmp_path / "legacy.npz")

    def strip(data, meta):
        meta.pop("checksum", None)
        meta["version"] = 1

    _rewrite(art, legacy, strip)
    eng2 = knn.load_engine(legacy, bn=bn)
    us = np.arange(g.n, dtype=np.int32)
    a, b = eng.query_batch(us), eng2.query_batch(us)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_save_with_pending_queue_raises_artifact_error(built, tmp_path):
    g, bn, objects, _, art = built
    eng = knn.load_engine(art, bn=bn)
    eng.stage_insert(next(v for v in range(g.n) if v not in set(eng.objects.tolist())))
    with pytest.raises(knn.ArtifactError):
        eng.save(str(tmp_path / "nope.npz"))
    with pytest.raises(RuntimeError):  # seed contract preserved
        eng.save(str(tmp_path / "nope.npz"))


def test_journal_torn_tail_truncated_and_recovered(built, tmp_path):
    """A partial frame from a kill mid-write (or trailing garbage) is
    detected by the length/CRC framing, truncated off, and everything
    before it replays — the engine recovers the acknowledged prefix."""
    g, bn, objects, _, art = built
    wal = str(tmp_path / "wal.bin")
    eng = knn.load_engine(art, bn=bn, journal=wal)
    mset = set(int(o) for o in objects)
    knn.stage_random_updates(eng, mset, rng=5, count=4)
    eng.flush_updates()
    knn.stage_random_updates(eng, mset, rng=6, count=3)
    good_size = os.path.getsize(wal)

    with open(wal, "ab") as f:  # torn frame: length promises more than exists
        f.write(b"\xff\x00\x00\x00\x12\x34\x56\x78partial")

    j = knn.UpdateJournal(wal)
    rec = knn.load_engine(art, bn=bn, journal=j)
    assert j.dropped_bytes > 0
    assert os.path.getsize(wal) >= good_size  # truncated back + tail commit

    eng.flush_updates()
    ri, ti = rec.to_index(), eng.to_index()
    assert np.array_equal(ri.ids, ti.ids)
    assert np.array_equal(ri.dists, ti.dists)


def test_journal_second_replay_reports_no_drops(built, tmp_path):
    """``dropped_bytes`` describes one replay, not the journal's history:
    the first replay truncates the torn tail off and reports it; a second
    replay of the now-clean file returns the same records and 0 — a
    monitoring loop polling the counter never double-counts a tail."""
    g, bn, objects, _, art = built
    wal = str(tmp_path / "wal.bin")
    eng = knn.load_engine(art, bn=bn, journal=wal)
    mset = set(int(o) for o in objects)
    knn.stage_random_updates(eng, mset, rng=8, count=3)
    with open(wal, "ab") as f:
        f.write(b"\x10\x00\x00\x00\xde\xad\xbe\xefshort")

    with knn.UpdateJournal(wal) as j:
        first = j.replay()
        assert j.dropped_bytes > 0
        assert [r[0] for r in first].count("commit") == 0 and len(first) == 3
        second = j.replay()
        assert second == first
        assert j.dropped_bytes == 0


def test_journal_bad_magic_raises(tmp_path):
    p = str(tmp_path / "notawal.bin")
    with open(p, "wb") as f:
        f.write(b"GARBAGE!and then some")
    with pytest.raises(knn.JournalError):
        knn.UpdateJournal(p)


def test_journal_truncates_on_save_not_on_flush(built, tmp_path):
    g, bn, objects, _, art = built
    wal = str(tmp_path / "wal.bin")
    eng = knn.load_engine(art, bn=bn, journal=wal)
    base = os.path.getsize(wal)
    mset = set(int(o) for o in objects)
    knn.stage_random_updates(eng, mset, rng=7, count=3)
    eng.flush_updates()
    # flush committed a marker but did NOT truncate: the artifact on disk
    # still predates the flush, the journal is the only durable copy
    assert os.path.getsize(wal) > base
    eng.save(str(tmp_path / "fresh.npz"))
    assert os.path.getsize(wal) == base  # now the artifact embodies it


def test_error_taxonomy_types():
    """Every typed error is a RepError AND the builtin it replaced, so both
    new ``except knn.RepError`` handlers and pre-taxonomy call sites work."""
    for err, builtin in [
        (knn.QueryError, ValueError),
        (knn.StagedUpdateError, ValueError),
        (knn.EngineConfigError, ValueError),
        (knn.EpochError, ValueError),
        (knn.ArtifactError, RuntimeError),
        (knn.JournalError, RuntimeError),
    ]:
        assert issubclass(err, knn.RepError)
        assert issubclass(err, builtin)
    assert issubclass(knn.JournalError, knn.ArtifactError)


def test_engine_raises_the_typed_errors(built):
    g, bn, objects, _, art = built
    eng = knn.load_engine(art, bn=bn)
    with pytest.raises(knn.QueryError):
        eng.query_batch(np.array([0, 1]), eng.k + 1)
    with pytest.raises(knn.QueryError):
        eng.query_batch(np.array([[0, 1]]))
    with pytest.raises(knn.StagedUpdateError):
        eng.stage_insert(-1)
    with pytest.raises(knn.StagedUpdateError):
        eng.stage_delete(next(v for v in range(g.n) if v not in set(eng.objects.tolist())))
    with pytest.raises(knn.StagedUpdateError):
        eng.stage_move(int(eng.objects[0]), int(eng.objects[0]))
    with pytest.raises(knn.EngineConfigError):
        eng.frontier = "gpu"
    with pytest.raises(knn.EpochError):
        eng.query_batch(np.array([0]), epoch=99)
