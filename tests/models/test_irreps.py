"""Irreps algebra: CG identities, Wigner-D, sh equivariance, and E(3)
invariance of the NequIP/MACE energies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.gnn import irreps, mace, nequip


def _random_rotation(seed):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def test_cg_dot_and_cross():
    c110 = irreps.real_cg(1, 1, 0)[:, :, 0]
    assert np.allclose(c110, np.eye(3) * c110[0, 0], atol=1e-12)
    c111 = irreps.real_cg(1, 1, 1)
    eps = np.zeros((3, 3, 3))
    for i, j, k in [(0, 1, 2), (1, 2, 0), (2, 0, 1)]:
        eps[i, j, k] = 1
        eps[j, i, k] = -1
    assert np.allclose(np.abs(c111), np.abs(eps) * np.abs(c111).max(), atol=1e-12)


def test_cg_orthonormal_columns():
    for (l1, l2, l3) in irreps.cg_paths(2):
        c = irreps.real_cg(l1, l2, l3).reshape(-1, 2 * l3 + 1)
        g = c.T @ c
        assert np.allclose(g, np.eye(2 * l3 + 1) * g[0, 0], atol=1e-10), (l1, l2, l3)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_wigner_orthogonal_and_sh_equivariant(seed):
    q = _random_rotation(seed)
    v = np.random.default_rng(seed).standard_normal((6, 3))
    sh_v = irreps.sh(jnp.asarray(v, jnp.float64), 2)
    sh_rv = irreps.sh(jnp.asarray(v @ q.T, jnp.float64), 2)
    for l in (1, 2):
        d = irreps.wigner_d(l, q)
        assert np.allclose(d @ d.T, np.eye(2 * l + 1), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(sh_rv[l]), np.asarray(sh_v[l]) @ d.T, rtol=1e-6, atol=1e-6
        )


@pytest.mark.parametrize("model", ["nequip", "mace"])
def test_energy_e3_invariance(model):
    rng = np.random.default_rng(0)
    n, e = 16, 40
    batch = {
        "species": jnp.asarray(rng.integers(0, 4, n), jnp.int32),
        "pos": jnp.asarray(rng.standard_normal((n, 3)) * 1.5, jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32),
        "graph_id": jnp.zeros((n,), jnp.int32),
        "graph_targets": jnp.zeros((1,), jnp.float32),
    }
    if model == "nequip":
        cfg = nequip.NequIPConfig(name="t", n_layers=2, d_hidden=8, n_species=4)
        mod = nequip
    else:
        cfg = mace.MACEConfig(name="t", n_layers=2, d_hidden=8, n_species=4)
        mod = mace
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    e1 = float(mod.loss_fn(params, batch, cfg))
    q = _random_rotation(3)
    batch2 = dict(batch, pos=batch["pos"] @ jnp.asarray(q.T, jnp.float32) + 7.5)
    e2 = float(mod.loss_fn(params, batch2, cfg))
    np.testing.assert_allclose(e1, e2, rtol=1e-4)


def test_mace_correlation_order_changes_output():
    """corr=3 must produce genuinely higher-order terms than corr=1."""
    rng = np.random.default_rng(1)
    n, e = 10, 24
    batch = {
        "species": jnp.asarray(rng.integers(0, 4, n), jnp.int32),
        "pos": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32),
        "graph_id": jnp.zeros((n,), jnp.int32),
        "graph_targets": jnp.zeros((1,), jnp.float32),
    }
    c3 = mace.MACEConfig(name="t", n_layers=1, d_hidden=8, n_species=4, correlation_order=3)
    c1 = mace.MACEConfig(name="t", n_layers=1, d_hidden=8, n_species=4, correlation_order=1)
    params = mace.init_params(jax.random.PRNGKey(0), c3)
    e3_ = float(mace.loss_fn(params, batch, c3))
    e1_ = float(mace.loss_fn(params, batch, c1))
    assert not np.isclose(e3_, e1_)
