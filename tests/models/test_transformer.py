"""Transformer internals: chunked attention oracle, prefill/decode parity,
MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import nn
from repro.models import transformer as tr


def _ref_attention(q, k, v, causal):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sc = jnp.einsum("bshd,bthd->bhst", q, k) / d**0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[1]), bool))
        sc = jnp.where(mask, sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", w, v)


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 32, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, 2, 8)), jnp.float32)
    for causal in (True, False):
        got = nn.chunked_attention(q, k, v, causal=causal, q_chunk=8, kv_chunk=16)
        want = _ref_attention(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_prefill_decode_match_full_forward():
    cfg = tr.TransformerConfig(
        name="t", n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, d_head=8,
        d_ff=96, vocab=64, qkv_bias=True, param_dtype=jnp.float32,
        q_chunk=8, kv_chunk=8,
    )
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    lg_pre, cache = tr.prefill(params, toks[:, :8], cfg, max_len=12)
    want = tr.forward(params, toks[:, :8], cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(want), rtol=3e-3, atol=3e-3)
    for i in range(8, 12):
        lg_dec, cache = tr.decode_step(params, cache, toks[:, i], cfg)
        want = tr.forward(params, toks[:, : i + 1], cfg)[:, -1]
        np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(want), rtol=3e-3, atol=3e-3)


def test_moe_routing_capacity_and_gates():
    cfg = tr.TransformerConfig(
        name="m", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_head=8,
        d_ff=32, vocab=32, n_experts=4, moe_top_k=2, param_dtype=jnp.float32,
    )
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    y = tr._moe_ffn(lp, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    # zero inputs -> zero outputs (no bias paths in expert mlp)
    y0 = tr._moe_ffn(lp, jnp.zeros_like(x), cfg)
    np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-6)


def test_moe_matches_dense_route_when_single_expert():
    """n_experts=1 top-1 MoE must equal the dense FFN with the same weights."""
    cfg = tr.TransformerConfig(
        name="m1", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_head=8,
        d_ff=32, vocab=32, n_experts=1, moe_top_k=1, capacity_factor=1.0,
        param_dtype=jnp.float32,
    )
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    y_moe = tr._moe_ffn(lp, x, cfg)
    dense_p = {
        "w_gate": {"w": lp["w_gate"][0]},
        "w_up": {"w": lp["w_up"][0]},
        "w_down": {"w": lp["w_down"][0]},
    }
    y_dense = tr._dense_ffn(dense_p, x)
    np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_dense), rtol=1e-5, atol=1e-5)


def test_param_count_matches_tree():
    cfg = tr.TransformerConfig(
        name="c", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab=100, param_dtype=jnp.float32,
    )
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    n_tree = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    # param_count excludes the (tiny) norm gains
    norms = cfg.n_layers * 2 * cfg.d_model + cfg.d_model
    assert n_tree == cfg.param_count() + norms
