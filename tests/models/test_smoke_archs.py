"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, get_arch
from repro.data import pipeline
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import steps as steps_mod

LM_ARCHS = [a for a in ASSIGNED if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ASSIGNED if get_arch(a).family == "gnn"]


def _one_train_step(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.make_smoke()
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    opt_cfg = adamw.AdamWConfig(total_steps=10)

    if arch.family == "lm":
        from repro.models import transformer as tr

        stream = pipeline.LMStream(vocab=cfg.vocab, batch=4, seq=32)
        fn, *_ = steps_mod.make_lm_train(cfg, rules, opt_cfg)
        params = tr.init_params(jax.random.PRNGKey(0), cfg)
    elif arch.family == "recsys":
        from repro.models import recsys as rc

        stream = pipeline.RecsysStream(n_sparse=cfg.n_sparse, bag=cfg.bag_size,
                                       rows=cfg.table_rows, batch=8)
        fn, *_ = steps_mod.make_recsys_train(cfg, rules, opt_cfg)
        params = rc.init_params(jax.random.PRNGKey(0), cfg)
    else:
        d_feat = getattr(cfg, "d_feat", 0)
        stream = pipeline.GraphStream(n_nodes=10, n_edges=24, batch=4, d_feat=d_feat,
                                      n_species=getattr(cfg, "n_species", 16))
        batch0 = jax.tree.map(jnp.asarray, stream.batch_at(0))
        fn, *_ = steps_mod.make_gnn_train(arch_id, cfg, rules, batch0, opt_cfg)
        mod = steps_mod.GNN_MODULES[arch_id]
        params = mod.init_params(jax.random.PRNGKey(0), cfg)

    opt_state = adamw.init(params)
    batch = jax.tree.map(jnp.asarray, stream.batch_at(0))
    params2, opt2, metrics = jax.jit(fn)(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    # params changed and stayed finite
    leaves = jax.tree.leaves(params2)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch_id
    return loss


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id):
    _one_train_step(arch_id)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_arch_smoke(arch_id):
    _one_train_step(arch_id)


def test_recsys_arch_smoke():
    _one_train_step("xdeepfm")


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
@pytest.mark.parametrize("shape", ["full_graph_sm", "molecule"])
def test_gnn_shape_variants_forward(arch_id, shape):
    """Reduced-size versions of the per-shape batch layouts run forward."""
    arch = get_arch(arch_id)
    cfg = arch.make_config(shape)
    # shrink: tiny synthetic batch with the same FIELD layout as the cell
    rng = np.random.default_rng(0)
    n, e = 24, 60
    batch = {
        "edge_index": jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32),
        "pos": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
    }
    if getattr(cfg, "d_feat", 0) > 0:
        batch["node_feat"] = jnp.asarray(rng.standard_normal((n, cfg.d_feat)), jnp.float32)
    else:
        batch["species"] = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
    task = getattr(cfg, "task", "node_class")
    if task == "energy":
        batch["graph_id"] = jnp.zeros((n,), jnp.int32)
        batch["graph_targets"] = jnp.zeros((1,), jnp.float32)
    else:
        ncls = getattr(cfg, "n_classes", getattr(cfg, "n_out", 2))
        batch["labels"] = jnp.asarray(rng.integers(0, ncls, n), jnp.int32)
    mod = steps_mod.GNN_MODULES[arch_id]
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    loss = mod.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), (arch_id, shape)


def test_lm_decode_smoke():
    from repro.models import transformer as tr

    cfg = get_arch("qwen2.5-3b").make_smoke()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits, cache = tr.prefill(params, toks, cfg, max_len=16)
    assert logits.shape == (2, cfg.vocab)
    logits2, cache = tr.decode_step(params, cache, jnp.argmax(logits, -1).astype(jnp.int32), cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert int(cache["len"]) == 13


def test_retrieval_cell_smoke():
    from repro.models import recsys as rc

    arch = get_arch("xdeepfm")
    cfg = arch.make_smoke()
    params = rc.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, cfg.table_rows, (1, cfg.n_sparse, cfg.bag_size)).astype(np.int32)
    oid, od = rc.retrieval_score(params, {"sparse_ids": jnp.asarray(ids),
                                          "n_candidates": cfg.table_rows}, cfg, k=5)
    assert oid.shape == (1, 5) and bool(jnp.isfinite(od).all())
