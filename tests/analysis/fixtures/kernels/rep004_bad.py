"""REP004 fixture: 64-bit dtypes in a kernel module (id/dist contract)."""
import jax.numpy as jnp
import numpy as np


def widen(ids, dists):
    wide = ids.astype(jnp.int64)
    d = dists.astype(np.float64)
    return wide, d.astype("float64")
