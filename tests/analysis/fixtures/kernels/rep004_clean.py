"""REP004 clean twin: the id=int32 / dist=float32 contract held."""
import jax.numpy as jnp
import numpy as np


def narrow(ids, dists):
    wide = ids.astype(jnp.int32)
    d = dists.astype(np.float32)
    return wide, d.astype("float32")
