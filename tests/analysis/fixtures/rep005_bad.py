"""REP005 fixture: module-level jnp computation (import-time device work)."""
import jax.numpy as jnp

TABLE = jnp.arange(1024) * 2  # allocates on device at import
