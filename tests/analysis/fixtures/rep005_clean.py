"""REP005 clean twin: metadata at import, computation inside functions."""
import jax.numpy as jnp
import numpy as np

_INF = jnp.finfo(jnp.float32).max  # metadata-only, no device allocation
_HOST_TABLE = np.arange(1024) * 2  # host numpy is free at import


def table():
    return jnp.asarray(_HOST_TABLE)
