"""REP002 clean twin: aliased operand fully read before the output write."""
import jax
from jax.experimental import pallas as pl


def kernel(x_ref, y_ref, o_ref):
    fresh = y_ref[...]  # read the aliased operand first (Jacobi discipline)
    o_ref[...] = x_ref[...] * 2 + fresh


def run(x, y):
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={1: 0},
    )(x, y)
