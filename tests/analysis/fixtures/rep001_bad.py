"""REP001 fixture: host materialization reachable from a jit boundary."""
import jax
import numpy as np


@jax.jit
def entry(x):
    return helper(x)


def helper(x):
    total = x.sum().item()  # host sync inside the serving path
    arr = np.asarray(x)  # host readback
    return float(total) + arr[0]
