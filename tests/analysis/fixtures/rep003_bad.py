"""REP003 fixture: recompile hazards — jit in a loop, tracer branch."""
import jax


def apply_all(fs, x):
    out = []
    for f in fs:
        g = jax.jit(f)  # fresh jit wrapper per iteration: compiles every call
        out.append(g(x))
    return out


@jax.jit
def gate(x, y):
    if x > 0:  # Python branch on a tracer
        return y * 2
    return y
