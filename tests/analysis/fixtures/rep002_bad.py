"""REP002 fixture: aliased Pallas operand read after the output scatter."""
import jax
from jax.experimental import pallas as pl


def kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * 2
    stale = y_ref[...]  # y aliases o: this reads the scattered buffer
    o_ref[...] = o_ref[...] + stale


def run(x, y):
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={1: 0},
    )(x, y)
