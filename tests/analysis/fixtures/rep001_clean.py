"""REP001 clean twin: the same shape of code, device-resident throughout.

The only ``.item()`` lives in a function no jit boundary reaches, and the
reachable helper touches metadata (shape/dtype) only.
"""
import jax
import jax.numpy as jnp


@jax.jit
def entry(x):
    return helper(x)


def helper(x):
    b = int(x.shape[0])  # static metadata, not a device read
    return jnp.sum(x) / b


def debug_print(x):  # never called from a boundary
    return x.sum().item()
