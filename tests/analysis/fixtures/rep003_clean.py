"""REP003 clean twin: module-level jit, lax.cond instead of Python branch."""
import jax
import jax.numpy as jnp


def _apply(f, x):
    return f(x)


apply_one = jax.jit(_apply, static_argnums=0)


@jax.jit
def gate(x, y):
    return jnp.where(x > 0, y * 2, y)
