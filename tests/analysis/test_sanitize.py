"""Runtime rail: transfer guard, compile budgets, table scans, aliasing.

The integration tests at the bottom pin the serving paths to the
checked-in ``tools/compile_budgets.json``: the warm counts must EQUAL the
budget (a warm compile is a recompile regression; a loose budget is
stale), the cold counts must fit under ``cold_max``.
"""
import json
import os
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import knn
from repro.analysis import sanitize
from repro.core.errors import SanitizerError
from repro.core.reference import knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network


# ---------------------------------------------------------------------------
# transfer guard
# ---------------------------------------------------------------------------


def test_no_transfers_blocks_numpy_into_jit():
    f = jax.jit(lambda x: x + 1)
    host = np.arange(8, dtype=np.int32)
    f(jnp.asarray(host))  # compile outside the guard
    with pytest.raises(SanitizerError, match="transfer"):
        with sanitize.no_transfers("test"):
            f(host).block_until_ready()


def test_no_transfers_allows_explicit_put_and_readback():
    f = jax.jit(lambda x: x + 1)
    host = np.arange(8, dtype=np.int32)
    f(jax.device_put(host))
    with sanitize.no_transfers("test"):
        out = f(jax.device_put(host))
        back = np.asarray(out)  # explicit d2h stays legal
    assert back[0] == 1


def test_guard_is_noop_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    f = jax.jit(lambda x: x * 2)
    with sanitize.guard("test"):
        f(np.arange(4, dtype=np.int32))  # implicit transfer, but guard is off


# ---------------------------------------------------------------------------
# compile counting + budgets
# ---------------------------------------------------------------------------


def test_count_compiles_cold_then_warm():
    def g(x):
        return x * 3 + 1

    gj = jax.jit(g)
    x = jnp.arange(97)  # shape unlikely to be cached by another test
    with sanitize.count_compiles() as cold:
        gj(x).block_until_ready()
    assert cold.count >= 1
    with sanitize.count_compiles() as warm:
        gj(x).block_until_ready()
    assert warm.count == 0


def test_assert_compiles_within(tmp_path, monkeypatch):
    budgets = tmp_path / "budgets.json"
    budgets.write_text('{"api": {"cold_max": 3, "warm": 0}}')
    monkeypatch.setenv("REPRO_COMPILE_BUDGETS", str(budgets))
    sanitize.assert_compiles_within("api", cold=3, warm=0)
    with pytest.raises(SanitizerError, match="cold"):
        sanitize.assert_compiles_within("api", cold=4)
    with pytest.raises(SanitizerError, match="warm"):
        sanitize.assert_compiles_within("api", warm=1)
    with pytest.raises(SanitizerError, match="no compile budget"):
        sanitize.assert_compiles_within("missing")


def test_count_transfers():
    with sanitize.count_transfers() as t:
        dev = jax.device_put(np.arange(8, dtype=np.int32))
        _ = np.asarray(dev)
    assert t.h2d == 1
    assert t.d2h == 1
    assert t.total == 2


# ---------------------------------------------------------------------------
# table scan
# ---------------------------------------------------------------------------


def _good_tables(n=6, k=3):
    ids = np.array([[1, 2, -1]] * n, np.int32)
    d = np.array([[0.5, 1.0, np.inf]] * n, np.float32)
    return ids, d


def test_scan_tables_accepts_valid():
    ids, d = _good_tables()
    sanitize.scan_tables(ids, d, 6)


@pytest.mark.parametrize(
    "mutate,msg",
    [
        (lambda ids, d: d.__setitem__((0, 0), np.nan), "NaN"),
        (lambda ids, d: d.__setitem__((0, 0), -1.0), "negative"),
        (lambda ids, d: ids.__setitem__((0, 0), 99), "outside"),
        (lambda ids, d: d.__setitem__((0, 2), 2.0), "pad slots"),
        (
            lambda ids, d: (
                ids.__setitem__((0, 0), -1),
                d.__setitem__((0, 0), np.inf),
            ),
            "right of pad",
        ),
        (lambda ids, d: d.__setitem__((0, 0), 1.5), "sorted"),
    ],
)
def test_scan_tables_rejects_corruption(mutate, msg):
    ids, d = _good_tables()
    mutate(ids, d)
    with pytest.raises(SanitizerError, match=msg):
        sanitize.scan_tables(ids, d, 6)


# ---------------------------------------------------------------------------
# aliasing sanitizer (poisoned kernels vs oracles)
# ---------------------------------------------------------------------------


def test_kernel_aliasing_oracle_parity():
    sanitize.check_kernel_aliasing(interpret=True)


# ---------------------------------------------------------------------------
# serving-path budgets (the checked-in tools/compile_budgets.json)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_engine():
    g = road_network(8, 8, seed=3)
    objects = pick_objects(g.n, 0.2, seed=3)
    bn = knn.build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k=4)
    return g, objects, knn.QueryEngine.from_index(idx, objects, bn=bn)


def test_query_batch_compile_budget(small_engine):
    g, objects, engine = small_engine
    us = np.arange(32, dtype=np.int32)
    with sanitize.count_compiles() as cold:
        engine.query_batch(us)
    with sanitize.count_compiles() as warm:
        engine.query_batch(us)
    sanitize.assert_compiles_within("query_batch", cold=cold.count, warm=warm.count)


def test_flush_updates_compile_budget(small_engine):
    g, objects, engine = small_engine
    obj_set = set(int(v) for v in np.asarray(objects).ravel())
    ins = [v for v in range(g.n) if v not in obj_set][:4]
    dels = sorted(obj_set)[:2]
    for v in ins:
        engine.stage_insert(v)
    for v in dels:
        engine.stage_delete(v)
    with sanitize.count_compiles() as cold:
        engine.flush_updates()
    # undo, then replay the same shapes: the warm path must not compile
    for v in ins:
        engine.stage_delete(v)
    for v in dels:
        engine.stage_insert(v)
    engine.flush_updates()
    for v in ins:
        engine.stage_insert(v)
    for v in dels:
        engine.stage_delete(v)
    with sanitize.count_compiles() as warm:
        engine.flush_updates()
    sanitize.assert_compiles_within("flush_updates", cold=cold.count, warm=warm.count)


# ---------------------------------------------------------------------------
# persistent compilation cache (cold-boot budget)
# ---------------------------------------------------------------------------

_COLD_BOOT = """
import json
import os

os.environ.setdefault("REPRO_COMPILE_CACHE", {cache!r})
import numpy as np
from repro.analysis import sanitize

# {how}: the dir flag and the env fallback are the same surface serve.py
# exposes via --compile-cache / REPRO_COMPILE_CACHE
assert sanitize.enable_compile_cache({arg}) is not None

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network

g = road_network(8, 8, seed=3)
objects = pick_objects(g.n, 0.2, seed=3)
bn = knn.build_bngraph(g)
idx = knn_index_cons_plus(bn, objects, k=4)
engine = knn.QueryEngine.from_index(idx, objects, bn=bn)
obj_set = set(int(v) for v in np.asarray(objects).ravel())
ins = [v for v in range(g.n) if v not in obj_set][:4]
with sanitize.count_compiles() as c:
    engine.query_batch(np.arange(32, dtype=np.int32))
    for v in ins:
        engine.stage_insert(v)
    engine.flush_updates()
print(json.dumps({{"count": c.count, "uncached": c.uncached}}))
"""


def test_compile_cache_cold_boot_budget(tmp_path, devices_subprocess):
    """A second process booting over a warm persistent cache dir must do
    no real compiles: its uncached count (backend compiles minus cache
    hits) must fit the *warm* serving budgets — a cold boot that recompiles
    is exactly the regression the cache exists to prevent."""
    cache = str(tmp_path / "xla-cache")
    first = json.loads(
        devices_subprocess(
            _COLD_BOOT.format(cache=cache, arg=repr(cache), how="dir flag"),
            n_devices=1,
        )
    )
    # the cold process really compiled, and every program landed in the dir
    assert first["uncached"] > 0
    assert any(os.scandir(cache))
    second = json.loads(
        devices_subprocess(
            _COLD_BOOT.format(cache=cache, arg=None, how="env fallback"),
            n_devices=1,
        )
    )
    budgets = json.loads(
        (Path(__file__).parents[2] / "tools" / "compile_budgets.json").read_text()
    )
    warm_budget = (
        budgets["query_batch"]["warm"] + budgets["flush_updates"]["warm"]
    )
    assert second["uncached"] <= warm_budget, (
        f"cold boot over a warm cache recompiled "
        f"{second['uncached']} programs (budget {warm_budget})"
    )


def test_enable_compile_cache_noop_without_path(monkeypatch):
    monkeypatch.delenv("REPRO_COMPILE_CACHE", raising=False)
    assert sanitize.enable_compile_cache(None) is None
