"""Static rail: fixture twins + pragma policy + repo-wide cleanliness.

Mutation-style coverage: every registered rule must own at least one
``<code>_bad.py`` fixture it fires on and a ``<code>_clean.py`` twin it
stays silent on. A rule that stops firing on its own fixture — or a new
rule added without fixtures — fails here, not in code review.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.replint import main, run
from repro.analysis.rules import all_rules

FIXTURES = Path(__file__).parent / "fixtures"
REPO = Path(__file__).resolve().parents[2]


def _codes(path: Path) -> set[str]:
    return {f.code for f in run([str(path)])}


@pytest.mark.parametrize("rule", all_rules(), ids=lambda r: r.code)
def test_rule_fires_on_bad_fixture(rule):
    bads = sorted(FIXTURES.rglob(f"{rule.code.lower()}_bad.py"))
    assert bads, f"{rule.code} has no firing fixture — add one under {FIXTURES}"
    for bad in bads:
        assert rule.code in _codes(bad), f"{rule.code} silent on {bad.name}"


@pytest.mark.parametrize("rule", all_rules(), ids=lambda r: r.code)
def test_rule_silent_on_clean_twin(rule):
    cleans = sorted(FIXTURES.rglob(f"{rule.code.lower()}_clean.py"))
    assert cleans, f"{rule.code} has no clean twin fixture"
    for clean in cleans:
        assert rule.code not in _codes(clean), f"{rule.code} fires on {clean.name}"


def test_clean_twins_are_fully_clean():
    # no rule may fire on another rule's clean twin either
    for clean in sorted(FIXTURES.rglob("*_clean.py")):
        findings = run([str(clean)])
        assert findings == [], "\n".join(f.render() for f in findings)


def test_repo_src_is_clean():
    findings = run([str(REPO / "src")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_exit_codes():
    bad = FIXTURES / "rep005_bad.py"
    clean = FIXTURES / "rep005_clean.py"
    assert main([str(bad)]) == 1
    assert main([str(clean)]) == 0
    assert main(["--list-rules"]) == 0


def test_select_filters_rules():
    bad = FIXTURES / "rep003_bad.py"
    assert {f.code for f in run([str(bad)], select={"REP003"})} == {"REP003"}
    assert run([str(bad)], select={"REP004"}) == []


def test_static_rail_is_stdlib_only():
    # the blocking CI job runs replint before jax is installed; importing
    # the static rail must never pull jax in
    code = (
        "import sys; import repro.analysis.replint; "
        "assert 'jax' not in sys.modules, 'static rail imported jax'"
    )
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


# ---------------------------------------------------------------------------
# pragma policy
# ---------------------------------------------------------------------------


def test_reasoned_pragma_suppresses(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax.numpy as jnp\n"
        "T = jnp.arange(8)  # replint: disable=REP005(test table, built once)\n"
    )
    assert run([str(f)]) == []


def test_bare_pragma_is_rejected(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax.numpy as jnp\n"
        "T = jnp.arange(8)  # replint: disable=REP005\n"
    )
    codes = {x.code for x in run([str(f)])}
    assert "REP000" in codes  # reasonless pragma is itself a finding
    assert "REP005" in codes  # and it does NOT suppress


def test_empty_reason_is_rejected(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax.numpy as jnp\n"
        "T = jnp.arange(8)  # replint: disable=REP005( )\n"
    )
    assert "REP000" in {x.code for x in run([str(f)])}


def test_def_line_pragma_covers_block(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax\n"
        "def fan(fs, x):  # replint: disable=REP003(wrappers cached by caller)\n"
        "    return [jax.jit(f)(x) for f in fs]\n"
    )
    assert run([str(f)]) == []


def test_pragma_does_not_leak_past_block(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax\n"
        "def fan(fs, x):  # replint: disable=REP003(wrappers cached by caller)\n"
        "    return [jax.jit(f)(x) for f in fs]\n"
        "def fan2(fs, x):\n"
        "    return [jax.jit(f)(x) for f in fs]\n"
    )
    assert {x.code for x in run([str(f)])} == {"REP003"}
