# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device. Multi-device behaviour is tested via subprocesses
# (tests/distributed/) that set --xla_force_host_platform_device_count.
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# Property tests import `hypothesis`, which is a declared test dependency
# (pyproject.toml) but absent from minimal images. Fall back to the vendored
# mini implementation so collection never fails on a clean checkout.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__), "_mini_hypothesis.py")
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


def run_devices_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run `code` in a subprocess with n fake CPU devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def devices_subprocess():
    return run_devices_subprocess
