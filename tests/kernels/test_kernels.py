"""Per-kernel shape/dtype sweeps: pallas (interpret) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rng(seed=0):
    return np.random.default_rng(seed)


@pytest.mark.parametrize("b", [1, 7, 128, 300])
@pytest.mark.parametrize("c,k", [(16, 3), (130, 10), (257, 20)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_topk_merge_sweep(b, c, k, dtype):
    rng = _rng(b * 1000 + c)
    ids = rng.integers(0, max(4, c // 3), size=(b, c)).astype(np.int32)
    ids[rng.random((b, c)) < 0.15] = -1
    d = np.round(rng.uniform(0, 64, size=(b, c)), 1).astype(dtype)
    got_i, got_d = ops.topk_merge(jnp.asarray(ids), jnp.asarray(d), k)
    want_i, want_d = ref.topk_merge_ref(jnp.asarray(ids), jnp.asarray(d), k)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(got_d, np.float32), posinf=1e30),
        np.nan_to_num(np.asarray(want_d, np.float32), posinf=1e30),
        rtol=1e-3,
    )


def test_topk_merge_all_invalid_row():
    ids = jnp.full((4, 20), -1, jnp.int32)
    d = jnp.zeros((4, 20), jnp.float32)
    got_i, got_d = ops.topk_merge(ids, d, 5)
    assert (np.asarray(got_i) == -1).all()
    assert np.isinf(np.asarray(got_d)).all()


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (70, 90, 130), (128, 256, 128)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_minplus_sweep(m, k, n, dtype):
    rng = _rng(m + k + n)
    a = rng.uniform(0, 50, size=(m, k)).astype(dtype)
    b = rng.uniform(0, 50, size=(k, n)).astype(dtype)
    got = ops.minplus_matmul(jnp.asarray(a), jnp.asarray(b), block_m=32, block_n=64, block_k=32)
    want = ref.minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_minplus_with_inf_padding():
    a = np.full((8, 8), np.inf, np.float32)
    a[0, 0] = 1.0
    b = np.full((8, 8), np.inf, np.float32)
    b[0, 0] = 2.0
    got = np.asarray(ops.minplus_matmul(jnp.asarray(a), jnp.asarray(b), block_m=8, block_n=8, block_k=8))
    assert got[0, 0] == 3.0 and np.isinf(got[1, 1])


def _frontier_case(seed, n, r, t, b, n_src):
    """Random frontier_relax instance with every pad convention exercised."""
    rng = _rng(seed)
    nbr = rng.integers(0, n, size=(r, t)).astype(np.int32)
    nbr[rng.random((r, t)) < 0.3] = -1          # padded neighbor slots
    rows = rng.choice(n, size=r, replace=False).astype(np.int32)
    rows[-1] = n                                 # padded receiver row
    w = np.where(nbr >= 0, rng.uniform(1, 9, size=nbr.shape), np.inf).astype(np.float32)
    dist = rng.uniform(0, 30, size=(n + 1, b)).astype(np.float32)
    dist[rng.random((n + 1, b)) < 0.4] = np.inf  # unreached entries
    dist[n] = np.inf                             # dummy row
    dist[:, n_src:] = np.inf                     # padded source columns
    kth = rng.uniform(0, 35, size=n + 1).astype(np.float32)
    kth[n] = np.inf
    src = np.full(b, -1, np.int32)
    src[:n_src] = rng.choice(n, size=n_src, replace=False)
    for i in range(n_src):                       # sources sit at distance 0
        dist[src[i], i] = 0.0
    return nbr, rows, w, dist, kth, src


@pytest.mark.parametrize("seed,n,r,t,b,n_src", [
    (0, 40, 9, 6, 8, 5),
    (1, 140, 9, 6, 128, 100),  # lane-aligned column count (TPU layout)
    (2, 150, 40, 17, 16, 11),  # receivers neighboring each other
    (3, 25, 6, 1, 8, 3),       # single neighbor column
])
def test_frontier_relax_pallas_vs_ref(seed, n, r, t, b, n_src):
    """The fused kernel must be bit-identical to the pure-Jacobi oracle even
    when receiver rows read each other: neighbor reads go through the
    non-aliased operand, so in-place receiver writes stay invisible."""
    args = [jnp.asarray(a) for a in _frontier_case(seed, n, r, t, b, n_src)]
    want = np.asarray(ref.frontier_relax_ref(*args))
    got_xla = np.asarray(ops.frontier_relax(*args, use_pallas=False))
    got_pl = np.asarray(ops.frontier_relax(*args, use_pallas=True))
    np.testing.assert_array_equal(got_xla, want)
    np.testing.assert_array_equal(got_pl, want)


def test_frontier_relax_gate_blocks_propagation():
    """A neighbor at dist >= kth must not propagate (checkIns), unless it is
    the column's source vertex — which always propagates."""
    n = 4
    nbr = np.array([[1]], np.int32)   # receiver 0 reads neighbor 1
    rows = np.array([0], np.int32)
    w = np.array([[2.0]], np.float32)
    dist = np.full((n + 1, 8), np.inf, np.float32)
    dist[1, 0] = 5.0                  # col 0: src elsewhere, 1 at 5.0
    dist[1, 1] = 0.0                  # col 1: 1 IS the source (dist 0)
    kth = np.full(n + 1, np.inf, np.float32)
    kth[1] = 4.0                      # gate closed: 5.0 >= 4.0, 0.0 < 4.0
    src = np.full(8, -1, np.int32)
    src[0] = 3
    src[1] = 1
    for use_pallas in (False, True):
        out = np.asarray(ops.frontier_relax(
            *[jnp.asarray(a) for a in (nbr, rows, w, dist, kth, src)],
            use_pallas=use_pallas,
        ))
        assert np.isinf(out[0, 0])        # blocked by the checkIns gate
        assert out[0, 1] == 2.0           # source column propagates at w
        np.testing.assert_array_equal(out[2:], dist[2:])  # untouched rows


def test_frontier_relax_all_pad_row_stays_inf():
    n = 6
    nbr = np.full((2, 3), -1, np.int32)
    rows = np.array([2, n], np.int32)
    w = np.full((2, 3), np.inf, np.float32)
    dist = np.full((n + 1, 8), np.inf, np.float32)
    kth = np.full(n + 1, np.inf, np.float32)
    src = np.full(8, -1, np.int32)
    for use_pallas in (False, True):
        out = np.asarray(ops.frontier_relax(
            *[jnp.asarray(a) for a in (nbr, rows, w, dist, kth, src)],
            use_pallas=use_pallas,
        ))
        assert np.isinf(out).all()


@pytest.mark.parametrize("b,n,k", [(1, 1024, 5), (8, 10000, 16), (3, 4096, 100)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_retrieval_topk_sweep(b, n, k, dtype):
    rng = _rng(b * n)
    s = rng.standard_normal((b, n)).astype(dtype)
    got_i, got_d = ops.retrieval_topk(jnp.asarray(s), k, block_b=1, block_n=1024)
    want_i, want_d = ref.retrieval_topk_ref(jnp.asarray(s), k)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


@pytest.mark.parametrize(
    "b,s,t,h,hkv,d,bq,bk,causal",
    [
        (2, 32, 32, 4, 2, 8, 8, 16, True),
        (1, 64, 64, 4, 4, 16, 16, 16, False),
        (2, 16, 16, 8, 2, 8, 16, 8, True),
        (1, 48, 48, 2, 1, 32, 16, 24, True),
    ],
)
def test_flash_attention_sweep(b, s, t, h, hkv, d, bq, bk, causal):
    rng = _rng(s * t)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), np.float32)
    k = jnp.asarray(rng.standard_normal((b, t, hkv, d)), np.float32)
    v = jnp.asarray(rng.standard_normal((b, t, hkv, d)), np.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = _rng(3)
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


def test_retrieval_topk_matches_lax_topk():
    rng = _rng(9)
    s = rng.standard_normal((4, 2048)).astype(np.float32)
    import jax

    want, _ = jax.lax.top_k(jnp.asarray(s), 7)
    _, got_d = ops.retrieval_topk(jnp.asarray(s), 7, block_b=4, block_n=512)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want), rtol=1e-6)
