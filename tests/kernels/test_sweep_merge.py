"""Edge cases for the dedup top-k merges: `topk_merge` and the fused
`sweep_merge`, both checked against the pure-jnp oracles in kernels/ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _assert_merge_equal(got, want):
    got_i, got_d = got
    want_i, want_d = want
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(got_d, np.float32), posinf=1e30),
        np.nan_to_num(np.asarray(want_d, np.float32), posinf=1e30),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# topk_merge edge cases (the unfused kernel the XLA path still uses elsewhere)
# ---------------------------------------------------------------------------

def test_topk_merge_duplicate_ids_span_lane_pad_boundary():
    """The same id on both sides of the 128-lane pad seam must dedup to the
    smaller distance, not appear twice."""
    c = 130  # pads to 256: columns 127/128 straddle the first lane boundary
    ids = np.full((4, c), -1, np.int32)
    d = np.full((4, c), np.inf, np.float32)
    ids[:, 127] = 7
    d[:, 127] = 5.0
    ids[:, 128] = 7
    d[:, 128] = 3.0
    ids[:, 0] = 1
    d[:, 0] = 4.0
    got = ops.topk_merge(jnp.asarray(ids), jnp.asarray(d), 3)
    want = ref.topk_merge_ref(jnp.asarray(ids), jnp.asarray(d), 3)
    _assert_merge_equal(got, want)
    got_i, got_d = got
    np.testing.assert_array_equal(np.asarray(got_i)[0], [7, 1, -1])
    np.testing.assert_allclose(np.asarray(got_d)[0, :2], [3.0, 4.0])


def test_topk_merge_all_invalid_rows():
    ids = jnp.full((8, 37), -1, jnp.int32)
    d = jnp.zeros((8, 37), jnp.float32)  # distances must be ignored
    got_i, got_d = ops.topk_merge(ids, d, 4)
    assert (np.asarray(got_i) == -1).all()
    assert np.isinf(np.asarray(got_d)).all()


def test_topk_merge_k_exceeds_distinct_candidates():
    ids = np.array([[3, 3, 5, 5, 3]], np.int32)
    d = np.array([[2.0, 1.0, 9.0, 8.0, 4.0]], np.float32)
    got_i, got_d = ops.topk_merge(jnp.asarray(ids), jnp.asarray(d), 6)
    np.testing.assert_array_equal(np.asarray(got_i)[0], [3, 5, -1, -1, -1, -1])
    np.testing.assert_allclose(np.asarray(got_d)[0, :2], [1.0, 8.0])
    assert np.isinf(np.asarray(got_d)[0, 2:]).all()


def test_topk_merge_distance_ties_pick_smaller_id():
    ids = np.array([[9, 2, 5, 2, 9]], np.int32)
    d = np.array([[1.0, 1.0, 1.0, 7.0, 7.0]], np.float32)
    got_i, got_d = ops.topk_merge(jnp.asarray(ids), jnp.asarray(d), 3)
    np.testing.assert_array_equal(np.asarray(got_i)[0], [2, 5, 9])
    np.testing.assert_allclose(np.asarray(got_d)[0], [1.0, 1.0, 1.0])
    _assert_merge_equal(
        (got_i, got_d), ref.topk_merge_ref(jnp.asarray(ids), jnp.asarray(d), 3)
    )


@pytest.mark.parametrize("c", [1, 5, 127, 129, 200, 257])
def test_topk_merge_non_multiple_of_128_widths(c):
    rng = np.random.default_rng(c)
    ids = rng.integers(-1, 30, size=(6, c)).astype(np.int32)
    d = np.round(rng.uniform(0, 9, size=(6, c)), 1).astype(np.float32)
    got = ops.topk_merge(jnp.asarray(ids), jnp.asarray(d), 5)
    want = ref.topk_merge_ref(jnp.asarray(ids), jnp.asarray(d), 5)
    _assert_merge_equal(got, want)


# ---------------------------------------------------------------------------
# sweep_merge: fused gather+shift+merge+scatter vs the unfused oracle
# ---------------------------------------------------------------------------

def _random_case(rng, *, n, chunk, t, k, e=None):
    e = k if e is None else e
    nbr = rng.integers(-1, n, size=(chunk, t)).astype(np.int32)
    verts = rng.choice(n, size=chunk, replace=False).astype(np.int32)
    nbr[np.isin(nbr, verts)] = -1  # level invariant: targets are never sources
    w = rng.uniform(0, 10, (chunk, t)).astype(np.float32)
    w[nbr < 0] = np.inf
    ex_ids = rng.integers(-1, n, size=(n + 1, e)).astype(np.int32)
    ex_d = rng.uniform(0, 50, (n + 1, e)).astype(np.float32)
    ex_d[ex_ids < 0] = np.inf
    ex_ids[n], ex_d[n] = -1, np.inf
    vk_ids = rng.integers(-1, n, size=(n + 1, k)).astype(np.int32)
    vk_d = np.sort(rng.uniform(0, 50, (n + 1, k)), axis=1).astype(np.float32)
    vk_d[vk_ids < 0] = np.inf
    vk_ids[n], vk_d[n] = -1, np.inf
    return tuple(jnp.asarray(x) for x in (nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d))


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize(
    "chunk,t,k",
    [(4, 1, 2), (8, 3, 5), (8, 7, 20), (4, 4, 3)],
)
def test_sweep_merge_matches_oracle(use_pallas, chunk, t, k):
    rng = np.random.default_rng(chunk * 100 + t * 10 + k)
    args = _random_case(rng, n=37, chunk=chunk, t=t, k=k)
    got = ops.sweep_merge(*args, k, use_pallas=use_pallas)
    want = ref.sweep_merge_ref(*args, k)
    _assert_merge_equal(got, want)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sweep_merge_untouched_rows_preserved(use_pallas):
    rng = np.random.default_rng(0)
    args = _random_case(rng, n=29, chunk=4, t=3, k=4)
    verts = np.asarray(args[1])
    got_i, got_d = ops.sweep_merge(*args, 4, use_pallas=use_pallas)
    untouched = np.setdiff1d(np.arange(30), verts)
    np.testing.assert_array_equal(
        np.asarray(got_i)[untouched], np.asarray(args[5])[untouched]
    )
    np.testing.assert_array_equal(
        np.asarray(got_d)[untouched], np.asarray(args[6])[untouched]
    )


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sweep_merge_all_invalid_neighbors_keeps_extras_only(use_pallas):
    n, chunk, t, k = 12, 4, 2, 3
    nbr = np.full((chunk, t), -1, np.int32)
    verts = np.arange(chunk, dtype=np.int32)
    w = np.full((chunk, t), np.inf, np.float32)
    ex_ids = np.full((n + 1, k), -1, np.int32)
    ex_d = np.full((n + 1, k), np.inf, np.float32)
    ex_ids[:chunk, 0] = np.arange(chunk) + 5
    ex_d[:chunk, 0] = 2.5
    vk_ids = np.full((n + 1, k), -1, np.int32)
    vk_d = np.full((n + 1, k), np.inf, np.float32)
    args = tuple(jnp.asarray(x) for x in (nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d))
    got_i, got_d = ops.sweep_merge(*args, k, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(got_i)[:chunk, 0], np.arange(chunk) + 5)
    np.testing.assert_allclose(np.asarray(got_d)[:chunk, 0], 2.5)
    assert (np.asarray(got_i)[:chunk, 1:] == -1).all()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sweep_merge_ties_and_dedup_across_neighbors(use_pallas):
    """Two neighbors both know object 3 at the same shifted distance; the
    merged row must keep one copy and tie-break equal distances by id."""
    n, chunk, t, k = 10, 4, 2, 3
    nbr = np.array([[0, 1]] * chunk, np.int32)
    verts = np.arange(4, 8).astype(np.int32)
    w = np.ones((chunk, t), np.float32)
    vk_ids = np.full((n + 1, k), -1, np.int32)
    vk_d = np.full((n + 1, k), np.inf, np.float32)
    vk_ids[0, :2] = [3, 8]
    vk_d[0, :2] = [1.0, 1.0]
    vk_ids[1, :2] = [3, 2]
    vk_d[1, :2] = [1.0, 1.0]
    ex_ids = np.full((n + 1, k), -1, np.int32)
    ex_d = np.full((n + 1, k), np.inf, np.float32)
    args = tuple(jnp.asarray(x) for x in (nbr, verts, w, ex_ids, ex_d, vk_ids, vk_d))
    got_i, got_d = ops.sweep_merge(*args, k, use_pallas=use_pallas)
    want_i, want_d = ref.sweep_merge_ref(*args, k)
    _assert_merge_equal((got_i, got_d), (want_i, want_d))
    np.testing.assert_array_equal(np.asarray(got_i)[4], [2, 3, 8])
    np.testing.assert_allclose(np.asarray(got_d)[4], [2.0, 2.0, 2.0])


def test_sweep_merge_candidate_width_not_multiple_of_128():
    """t*k+e far from a lane multiple exercises the scratch padding path."""
    rng = np.random.default_rng(3)
    args = _random_case(rng, n=41, chunk=8, t=5, k=7)  # width 42
    got = ops.sweep_merge(*args, 7, use_pallas=True)
    want = ref.sweep_merge_ref(*args, 7)
    _assert_merge_equal(got, want)
