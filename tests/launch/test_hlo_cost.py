"""Structural HLO cost model: loop trip-count correction (the basis of every
roofline number in EXPERIMENTS.md §Roofline)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_cost_analysis_undercounts_scan_and_we_correct_it():
    x = jnp.zeros((64, 128))
    w = jnp.zeros((32, 128, 128))

    def scan_fn(x, w):
        def body(c, wi):
            return c @ wi, None

        y, _ = jax.lax.scan(body, x, w)
        return y

    def unroll_fn(x, w):
        for i in range(32):
            x = x @ w[i]
        return x

    expected = 2 * 64 * 128 * 128 * 32
    compiled = jax.jit(scan_fn).lower(x, w).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    raw = ca.get("flops", 0.0)
    assert raw < expected / 4, "XLA cost_analysis counts loop bodies once"

    corrected = analyze(compiled.as_text())
    assert corrected["flops"] == expected
    assert 32 in corrected["loops"].values()
    # the unrolled program agrees
    assert analyze(_compiled_text(unroll_fn, x, w))["flops"] == expected


def test_nested_loops_multiply():
    x = jnp.zeros((16, 16))
    w = jnp.zeros((4, 16, 16))

    def fn(x, w):
        def outer(c, _):
            def inner(c2, wi):
                return c2 @ wi, None

            c, _ = jax.lax.scan(inner, c, w)
            return c, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    got = analyze(_compiled_text(fn, x, w))["flops"]
    assert got == 2 * 16 * 16 * 16 * 4 * 5


def test_collectives_weighted_by_trips():
    hlo = """
%body (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %arg = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8]{0} get-tuple-element(%arg), index=1
  %ar = f32[8]{0} all-reduce(%x), to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ni, %ar)
}

%cond (arg: (s32[], f32[8])) -> pred[] {
  %arg = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8]) tuple(%zero, %p)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    r = analyze(hlo)
    assert r["collective_counts"]["all-reduce"] == 10
    assert r["collective_bytes"]["all-reduce"] == 10 * 8 * 4


def test_traffic_windows_dynamic_slice():
    hlo = """
ENTRY %main (p: f32[1024,1024]) -> f32[8,1024] {
  %p = f32[1024,1024]{1,0} parameter(0)
  %z = s32[] constant(0)
  ROOT %ds = f32[8,1024]{1,0} dynamic-slice(%p, %z, %z), dynamic_slice_sizes={8,1024}
}
"""
    r = analyze(hlo)
    # windowed: 2x output, NOT the 4 MB operand
    assert r["traffic_bytes"] == 3 * 8 * 1024 * 4
