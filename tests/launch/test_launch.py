"""Launcher-level tests: dry-run machinery on a small mesh, HLO parsing,
end-to-end train driver with checkpoint resume."""
import subprocess
import sys


from conftest import REPO, run_devices_subprocess
from repro.launch.hlo_analysis import _shape_bytes, collective_stats

DRYRUN_SMALL = r"""
import jax
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import make_rules
from repro.configs.registry import get_arch
from repro.launch import dryrun
from pathlib import Path
import tempfile

assert len(jax.devices()) == 8
# monkeypatch the production mesh to the 8-device test mesh
dryrun.make_production_mesh = lambda multi_pod=False: make_mesh(
    (2, 2, 2) if multi_pod else (4, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
arch = get_arch("gcn-cora")
out = Path(tempfile.mkdtemp())
rec = dryrun.run_cell(arch, "molecule", arch.shapes["molecule"], multi_pod=True, out_dir=out)
assert rec["n_chips"] == 8
assert rec["per_device"]["flops"] > 0
assert rec["bottleneck"] in ("compute_s", "memory_s", "collective_s")
assert len(list(out.glob("*.json"))) == 1
print("DRYRUN_SMALL_OK")
"""


def test_dryrun_machinery_small_mesh():
    out = run_devices_subprocess(DRYRUN_SMALL, n_devices=8)
    assert "DRYRUN_SMALL_OK" in out


def test_hlo_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1


def test_collective_stats_parsing():
    hlo = """
  %ar = f32[16,4]{1,0} all-reduce(f32[16,4]{1,0} %x), replica_groups={}
  %ag.1 = bf16[32]{0} all-gather(bf16[16]{0} %y), dimensions={0}
  %st = f32[8]{0} all-reduce-start(f32[8]{0} %z)
  %dn = f32[8]{0} all-reduce-done(f32[8]{0} %st)
"""
    s = collective_stats(hlo)
    assert s["counts"]["all-reduce"] == 2  # plain + start (done skipped)
    assert s["bytes_per_device"]["all-gather"] == 64
    assert s["total_bytes_per_device"] == 16 * 4 * 4 + 64 + 32


def test_knn_build_then_serve_artifact(tmp_path):
    """knn_build --out writes a QueryEngine artifact that serve.py (dispatched
    to the knn family) loads and serves under mixed query+update traffic."""
    import json
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    art = str(tmp_path / "index.npz")
    build = subprocess.run(
        [sys.executable, "-m", "repro.launch.knn_build",
         "--grid", "10", "--k", "4", "--mu", "0.2", "--out", art],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert build.returncode == 0, build.stderr
    stats = json.loads(build.stdout)
    assert stats["index_bytes"] == stats["n"] * stats["k"] * 8
    assert os.path.exists(art)

    serve = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "knn-index", "--smoke", "--grid", "10", "--k", "4",
         "--mu", "0.2", "--ops", "600", "--query-batch", "128",
         "--update-frac", "0.05", "--artifact", art],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert serve.returncode == 0, serve.stderr
    out = json.loads(serve.stdout)
    assert out["arch"] == "knn-index"
    assert out["queries"] > 0 and out["queries_per_s"] > 0
    assert out["updates"] > 0
    assert out["engine"]["staged_queue_depth"] == 0  # all flushed
    assert out["engine"]["flushes"] > 0


def test_serve_rejects_unknown_family():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gcn-cora"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert p.returncode != 0
    assert "families" in p.stderr


def test_train_driver_resume(tmp_path):
    env_cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "6",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--log-every", "2",
    ]
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    p1 = subprocess.run(env_cmd, capture_output=True, text=True, env=env, timeout=600)
    assert p1.returncode == 0, p1.stderr
    env_cmd[env_cmd.index("--steps") + 1] = "8"
    p2 = subprocess.run(env_cmd, capture_output=True, text=True, env=env, timeout=600)
    assert p2.returncode == 0, p2.stderr
    assert "resumed from step 6" in p2.stdout


def test_serve_auto_ranges_drift_resplit():
    """ranges=auto is a continuous drift detector: the initial skew triggers
    a first re-split, then --hot-flip-round moves the zipf city to another
    shard's range and the detector must fire a *second* repartition after
    the cooldown — plus the collective halo serves every multi-shard flush
    without falling back."""
    import json
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    flip = 8
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "knn-index", "--smoke", "--grid", "10", "--k", "4",
         "--batch", "128", "--ops", "2500", "--seed", "3",
         "--partition", "shards=4,ranges=auto",
         "--hot-shard", "0", "--hot-frac", "0.9",
         "--hot-flip-round", str(flip)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert p.returncode == 0, p.stderr
    out = json.loads(p.stdout)
    resplits = out["repartition_rounds"]
    assert len(resplits) >= 2, resplits
    assert resplits[0] < flip  # warmup skew caught before the flip
    assert any(r >= flip for r in resplits)  # the moved city caught after
    assert out["repartitioned_at_round"] == resplits[0]
    assert out["errors"] == 0
    assert out["engine"]["halo"] == "collective"
    assert out["engine"]["halo_rounds_collective"] > 0
    assert out["engine"]["halo_fallbacks"] == 0
