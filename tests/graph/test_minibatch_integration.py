"""minibatch_lg integration: real neighbor sampler -> padded subgraph ->
GNN train step (the full sampled-training pipeline at reduced scale)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import make_rules
from repro.graph.generators import road_network
from repro.graph.sampler import pad_subgraph, sample_khop
from repro.launch.mesh import make_host_mesh
from repro.models.gnn import gcn
from repro.optim import adamw
from repro.train import steps as steps_mod


def test_sampled_training_pipeline():
    g = road_network(20, 20, seed=0)  # stand-in for the 233k-node graph
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((g.n, 32)).astype(np.float32)
    labels = rng.integers(0, 5, g.n).astype(np.int32)

    cfg = gcn.GCNConfig(name="mb", n_layers=2, d_hidden=8, d_feat=32, n_classes=5)
    params = gcn.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    mesh = make_host_mesh()
    rules = make_rules(mesh)

    n_pad, e_pad = 256, 1024
    losses = []
    fn = None
    for step in range(3):
        seeds = rng.choice(g.n, size=16, replace=False)
        sub = sample_khop(g, seeds, (4, 3), seed=step)
        sub = pad_subgraph(sub, n_pad, e_pad)
        batch = {
            "node_feat": jnp.asarray(feats[sub.nodes]),
            "edge_index": jnp.asarray(sub.edge_index),
            "labels": jnp.asarray(labels[sub.nodes]),
        }
        if fn is None:
            fn, *_ = steps_mod.make_gnn_train(
                "gcn-cora", cfg, rules, jax.tree.map(lambda x: x, batch),
                adamw.AdamWConfig(total_steps=10),
            )
            fn = jax.jit(fn)
        params, opt_state, metrics = fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    # static shapes -> single compilation across steps
    assert len(losses) == 3
