"""Graph substrate: CSR invariants, generators, k-hop sampler."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.graph.csr import from_edges, is_connected
from repro.graph.generators import pick_objects, random_connected_graph, road_network
from repro.graph.sampler import pad_subgraph, sample_khop


def test_from_edges_symmetry_and_min_parallel():
    g = from_edges(4, [(0, 1, 3.0), (1, 0, 2.0), (1, 2, 5.0), (2, 3, 1.0)])
    nbrs, ws = g.neighbors(0)
    assert list(nbrs) == [1] and list(ws) == [2.0]  # parallel edge keeps min
    nbrs1, _ = g.neighbors(1)
    assert 0 in nbrs1 and 2 in nbrs1


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 60), st.integers(0, 80), st.integers(0, 1000))
def test_random_graph_connected(n, extra, seed):
    g = random_connected_graph(n, extra_edges=extra, seed=seed)
    assert is_connected(g)
    # CSR degree bookkeeping consistent
    assert g.indptr[-1] == len(g.indices)


def test_road_network_stats():
    g = road_network(20, 20, seed=0)
    assert g.n == 400 and is_connected(g)
    deg = g.degrees()
    assert deg.mean() < 5  # road-like sparsity


def test_sampler_fanout_bounds():
    g = road_network(15, 15, seed=1)
    seeds = np.asarray([0, 7, 30], dtype=np.int64)
    sub = sample_khop(g, seeds, (4, 3), seed=0)
    # every seed present, edges reference valid local ids
    assert len(sub.seeds_local) == 3
    assert sub.edge_index.max() < len(sub.nodes)
    # fanout bound: layer1 <= 3*4 edges, layer2 <= (3*4)*3
    assert sub.edge_index.shape[1] <= 3 * 4 + 3 * 4 * 3
    padded = pad_subgraph(sub, 256, 512)
    assert padded.edge_index.shape == (2, 512) and len(padded.nodes) == 256


def test_pick_objects_density():
    m = pick_objects(1000, 0.05, seed=0)
    assert len(m) == 50 and len(np.unique(m)) == 50
