"""FleetSim: movement-trace invariants + end-to-end engine equivalence."""
import numpy as np
import pytest

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.workloads.fleet import FleetSim, shortest_path


def test_shortest_path_is_a_valid_shortest_path():
    g = knn.road_network(8, 8, seed=0)
    adj = {v: dict(zip(*[x.tolist() for x in g.neighbors(v)])) for v in range(g.n)}
    rng = np.random.default_rng(0)
    for _ in range(10):
        s, t = rng.integers(0, g.n, size=2)
        path = shortest_path(g, int(s), int(t))
        assert path[0] == s and path[-1] == t
        total = sum(adj[a][b] for a, b in zip(path, path[1:]))
        # compare against an independent Dijkstra distance
        import heapq

        dist = {int(s): 0.0}
        heap = [(0.0, int(s))]
        while heap:
            d, v = heapq.heappop(heap)
            if d > dist.get(v, np.inf):
                continue
            for nb, w in adj[v].items():
                nd = d + w
                if nd < dist.get(nb, np.inf):
                    dist[nb] = nd
                    heapq.heappush(heap, (nd, nb))
        assert np.isclose(total, dist[int(t)])


def test_tick_moves_are_stageable_and_collision_free():
    g = knn.road_network(10, 10, seed=1)
    sim = FleetSim(g, fleet_size=30, seed=1)
    positions = set(sim.positions.tolist())
    assert len(positions) == 30
    for _ in range(12):
        occupied = set(positions)
        for u, v in sim.tick():
            # exactly the stage_move contract, replayed on a host mirror
            assert u in occupied and v not in occupied
            occupied.discard(u)
            occupied.add(v)
        positions = occupied
        assert positions == set(sim.positions.tolist())
        assert len(positions) == 30  # vehicles never merge


def test_fleet_is_deterministic_per_seed():
    g = knn.road_network(8, 8, seed=2)
    sim_a = FleetSim(g, fleet_size=16, seed=7)
    sim_b = FleetSim(g, fleet_size=16, seed=7)
    assert [sim_a.tick() for _ in range(5)] == [sim_b.tick() for _ in range(5)]


def test_fleet_size_validation():
    g = knn.road_network(4, 4, seed=0)
    with pytest.raises(ValueError):
        FleetSim(g, fleet_size=g.n, seed=0)
    with pytest.raises(ValueError):
        FleetSim(g, fleet_size=0, seed=0)
    with pytest.raises(ValueError):
        FleetSim(g, fleet_size=4, seed=0, steps_per_tick=0)


def test_fleet_trace_through_engine_matches_rebuild():
    """Ticks staged as fused moves land on the rebuild-from-scratch index."""
    g = knn.road_network(10, 10, seed=3)
    bn = knn.build_bngraph(g)
    k = 4
    sim = FleetSim(g, fleet_size=24, seed=3)
    engine = knn.build_engine(bn, sim.positions, k)
    for _ in range(6):
        for u, v in sim.tick():
            engine.stage_move(u, v)
        engine.flush_updates()
    assert np.array_equal(engine.objects, sim.positions)
    fresh = knn_index_cons_plus(bn, sim.positions, k)
    assert knn.indices_equivalent(fresh, engine.to_index())
    assert engine.stats()["moves_applied"] > 0
