"""Tiny stand-in for the slice of the `hypothesis` API this suite uses.

The real library is the declared test dependency (see pyproject.toml); this
fallback keeps the suite runnable on minimal images where it is absent.
Installed into ``sys.modules["hypothesis"]`` by tests/conftest.py only when
the import fails, so environments with hypothesis installed are unaffected.

Coverage: ``given``, ``settings(max_examples=, deadline=)`` and the
``st.tuples`` / ``st.integers`` / ``st.floats`` / ``st.booleans`` /
``st.sampled_from`` strategies. Unlike the real thing there is no shrinking
and the draw sequence is deterministic per test (seeded from the test name),
so failures reproduce exactly.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from types import SimpleNamespace

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def _sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool))


def _tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


strategies = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    booleans=_booleans,
    sampled_from=_sampled_from,
    tuples=_tuples,
)


class settings:  # noqa: N801 — mirrors the hypothesis name
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._mini_hypothesis_settings = self
        return fn


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_mini_hypothesis_settings", None) or getattr(
                fn, "_mini_hypothesis_settings", None
            )
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = tuple(s.draw(rng) for s in arg_strategies)
                kdrawn = {name: s.draw(rng) for name, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **kdrawn)

        # Hide the strategy-driven parameters from pytest's fixture resolver
        # (functools.wraps exposes them via __wrapped__ / the copied signature).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


HealthCheck = SimpleNamespace(too_slow="too_slow", data_too_large="data_too_large")
