"""Distributed-semantics tests under 8 fake CPU devices (subprocesses, so the
main pytest process keeps its single real device)."""

from conftest import run_devices_subprocess

SHARDED_EQ = r"""
import jax, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import make_rules
from repro.train import steps as S
from repro.optim import adamw
from repro.models import transformer as tr
from repro.data.pipeline import LMStream

assert len(jax.devices()) == 8, jax.devices()
cfg = tr.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                           d_head=8, d_ff=64, vocab=64, param_dtype=jnp.float32,
                           q_chunk=8, kv_chunk=8)
stream = LMStream(vocab=cfg.vocab, batch=8, seq=16)
batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
params = tr.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)

mesh = make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
fn, ins, outs, _ = S.make_lm_train(cfg, rules, adamw.AdamWConfig(total_steps=10))
from jax.sharding import NamedSharding, PartitionSpec as P
shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
with mesh:
    jitted = jax.jit(fn, in_shardings=shard(ins), out_shardings=shard(outs))
    p1, o1, m1 = jitted(params, opt, batch)

# single-device reference
mesh1 = make_mesh((1, 1), ("data", "model"))
rules1 = make_rules(mesh1)
fn1, *_ = S.make_lm_train(cfg, rules1, adamw.AdamWConfig(total_steps=10))
p2, o2, m2 = jax.jit(fn1)(params, opt, batch)

np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=5e-4, atol=5e-5)
print("SHARDED_EQ_OK")
"""


def test_sharded_train_step_matches_single_device():
    out = run_devices_subprocess(SHARDED_EQ, n_devices=8)
    assert "SHARDED_EQ_OK" in out


ELASTIC = r"""
import jax, numpy as np, jax.numpy as jnp, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed import elastic
from repro.checkpoint import manager as ckpt

assert len(jax.devices()) == 8
mesh8 = make_mesh((4, 2), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh8, P("data", "model")))
tmp = tempfile.mkdtemp()
ckpt.save(tmp, 3, {"x": xs})

# lose 4 devices -> rebuild mesh, restore under new shardings
surv = elastic.simulate_failures(jax.devices(), lost=4)
mesh4 = elastic.surviving_mesh(surv, model_axis=2)
assert dict(mesh4.shape) == {"data": 2, "model": 2}, mesh4.shape
shd = {"x": NamedSharding(mesh4, P("data", "model"))}
restored, step = ckpt.restore(tmp, {"x": x}, shardings=shd)
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert elastic.global_batch_for(mesh4, per_device_batch=4) == 8
print("ELASTIC_OK")
"""


def test_elastic_shrink_and_reshard():
    out = run_devices_subprocess(ELASTIC, n_devices=8)
    assert "ELASTIC_OK" in out


COMPRESSION = r"""
import jax, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.distributed.compression import make_compressed_grad_reduce

assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("data",))
reduce_fn = make_compressed_grad_reduce(mesh, ("data",))
rng = np.random.default_rng(0)
g = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
r = {"w": jnp.zeros((32, 32), jnp.float32)}
with mesh:
    mean1, r1 = reduce_fn(g, r)
# all replicas share g (replicated input) -> mean == dequant(quant(g)) approx g
err1 = float(jnp.abs(mean1["w"] - g["w"]).max())
assert err1 < 0.05, err1
# error feedback: residual carries the quantisation error
with mesh:
    mean2, r2 = reduce_fn(g, r1)
two_step = np.asarray(mean1["w"] + mean2["w"]) / 2
err2 = float(np.abs(two_step - np.asarray(g["w"])).max())
assert err2 < err1, (err1, err2)
print("COMPRESSION_OK", err1, err2)
"""


def test_compressed_allreduce_error_feedback():
    out = run_devices_subprocess(COMPRESSION, n_devices=8)
    assert "COMPRESSION_OK" in out


KNN_DISTRIBUTED = r"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import make_rules
from repro.train import steps as S
from repro.configs.knn_index import make_smoke
from repro.graph.generators import road_network, pick_objects
from repro.core.bngraph import build_bngraph
from repro.core.reference import knn_index_cons_plus
from repro.core.construct_jax import build_knn_index_jax
from repro.core.index import indices_equivalent

assert len(jax.devices()) == 8
# distributed serve: sharded index rows, replicated queries
mesh = make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
cfg = make_smoke()
fn, ins, outs, _ = S.make_knn_serve(cfg, rules)
g = road_network(16, 16, seed=0)
M = pick_objects(g.n, 0.2, seed=0)
bn = build_bngraph(g)
idx = build_knn_index_jax(bn, M, cfg.k, use_pallas=False)
rows = ((g.n + 1 + 7) // 8) * 8
vk_ids = np.full((rows, cfg.k), -1, np.int32); vk_ids[:g.n] = idx.ids
vk_d = np.full((rows, cfg.k), np.inf, np.float32); vk_d[:g.n] = idx.dists
queries = np.arange(0, g.n, 3, dtype=np.int32)[:32]
shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
with mesh:
    out_ids, out_d = jax.jit(fn, in_shardings=shard(ins), out_shardings=shard(outs))(
        jnp.asarray(vk_ids), jnp.asarray(vk_d), jnp.asarray(queries))
np.testing.assert_array_equal(np.asarray(out_ids), vk_ids[queries])
ref = knn_index_cons_plus(bn, M, cfg.k)
assert indices_equivalent(ref, idx)
print("KNN_DISTRIBUTED_OK")
"""


def test_knn_distributed_serve():
    out = run_devices_subprocess(KNN_DISTRIBUTED, n_devices=8)
    assert "KNN_DISTRIBUTED_OK" in out
