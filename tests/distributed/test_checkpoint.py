"""Checkpoint manager: atomic commit, resume, pruning."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16), "c": jnp.asarray(3, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 7, t)
    restored, step = ckpt.restore(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, t)
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.prune(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert sorted(p.name for p in tmp_path.iterdir()) == ["step_00000003", "step_00000004"]


def test_incomplete_tmp_dir_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 5, t)
    # simulate a crash mid-save: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(tmp_path) == 5
    restored, step = ckpt.restore(tmp_path, t)
    assert step == 5


def test_dtype_restored_via_like(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    restored, _ = ckpt.restore(tmp_path, t)
    assert restored["nested"]["b"].dtype == jnp.bfloat16
