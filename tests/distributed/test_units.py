"""Single-process units for the fault-tolerance/straggler/compression pieces."""
import numpy as np

from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.distributed.straggler import StepTimer, quorum_ok


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    import jax.numpy as jnp

    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    max_err = float(np.abs(np.asarray(back) - np.asarray(x)).max())
    assert max_err <= float(scale) * 0.5 + 1e-6  # half-ULP of the int8 grid


def test_step_timer_deadline():
    t = StepTimer(tolerance=2.0, alpha=0.5)
    assert t.deadline == float("inf")
    t.update(1.0)
    t.update(1.0)
    assert abs(t.mean - 1.0) < 1e-9
    assert abs(t.deadline - 2.0) < 1e-9


def test_quorum():
    assert quorum_ok(0.97, quorum=0.95)
    assert not quorum_ok(0.90, quorum=0.95)
