"""Snapshot isolation: queries never observe a mid-flush state.

The chaos hook here does not kill anything — it issues queries from INSIDE
the flush pipeline, at every checkpoint phase, and the property (ISSUE 6
acceptance) is that each one returns results bit-identical to epoch e
(before the swap) or epoch e+1 (after it), never a mixture of
partially-repaired rows. Plus the retention surface: ``keep_epochs``
bounds what ``query_batch(..., epoch=)`` can pin, and eviction raises the
typed ``EpochError``.
"""
import numpy as np
import pytest

from repro import knn
from repro.graph.generators import pick_objects, road_network

ENGINES = ["scalar", "sharded"]


def _setup(grid=8, mu=0.2, k=4, seed=0):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    return g, bn, objects, k


def _build(kind, bn, objects, k):
    if kind == "scalar":
        return knn.build_engine(bn, objects, k)
    return knn.build_sharded_engine(bn, objects, k, shards=None)


def _stage_mix(eng, mset, seed, count=5):
    knn.stage_random_updates(eng, mset, rng=seed, count=count)
    u = sorted(mset)[0]
    v = next(w for w in range(eng.n) if w not in mset)
    eng.stage_move(u, v)
    mset.discard(u)
    mset.add(v)


@pytest.mark.parametrize("kind", ENGINES)
def test_queries_never_observe_mid_flush_state(kind, tmp_path):
    g, bn, objects, k = _setup()
    eng = _build(kind, bn, objects, k)
    eng.attach_journal(str(tmp_path / "wal.bin"))
    mset = set(int(o) for o in objects)
    us = np.arange(g.n, dtype=np.int32)

    bi, bd = eng.query_batch(us)
    before = (np.asarray(bi), np.asarray(bd))

    seen: dict[str, tuple] = {}

    def probe(e, phase):
        ids, d = e.query_batch(us)
        # record the FIRST observation per phase (mid-repair fires per round)
        seen.setdefault(phase, (np.asarray(ids), np.asarray(d)))

    eng.checkpoint_hook = probe
    _stage_mix(eng, mset, seed=7)  # move included -> repair rounds run
    eng.flush_updates()
    eng.checkpoint_hook = None

    ai, ad = eng.query_batch(us)
    after = (np.asarray(ai), np.asarray(ad))
    # the flush changed something, so "whole epoch" is a real distinction
    assert not np.array_equal(before[0], after[0]) or not np.array_equal(
        before[1], after[1]
    )

    for phase in ("post-journal-append", "mid-repair-round", "pre-swap", "post-swap"):
        assert phase in seen, f"phase {phase} never fired"
        want = after if phase == "post-swap" else before
        ids, d = seen[phase]
        assert np.array_equal(ids, want[0]), f"{phase}: ids tore"
        assert np.array_equal(d, want[1]), f"{phase}: dists tore"


@pytest.mark.parametrize("kind", ENGINES)
def test_epoch_pinning_and_retention(kind):
    g, bn, objects, k = _setup()
    eng = _build(kind, bn, objects, k)
    mset = set(int(o) for o in objects)
    us = np.arange(g.n, dtype=np.int32)

    eng.keep_epochs = 3
    per_epoch = {eng.epoch: tuple(np.asarray(a) for a in eng.query_batch(us))}
    for seed in (11, 12, 13):
        _stage_mix(eng, mset, seed=seed)
        eng.flush_updates()
        per_epoch[eng.epoch] = tuple(np.asarray(a) for a in eng.query_batch(us))

    assert eng.epoch == 3
    assert eng.retained_epochs() == [1, 2, 3]  # epoch 0 evicted (keep=3)

    # pinned reads reproduce each retained epoch bit-identically
    for e in eng.retained_epochs():
        ids, d = eng.query_batch(us, epoch=e)
        assert np.array_equal(np.asarray(ids), per_epoch[e][0])
        assert np.array_equal(np.asarray(d), per_epoch[e][1])

    # the evicted epoch raises the typed error
    with pytest.raises(knn.EpochError):
        eng.query_batch(us, epoch=0)
    with pytest.raises(knn.EpochError):
        eng.epoch_stats(0)

    # memory bound surfaces in stats and tracks the retention knob
    s = eng.stats()
    assert s["epochs_retained"] == 3
    assert s["epoch_table_bytes"] == 3 * eng._table_bytes()
    eng.keep_epochs = 1
    assert eng.retained_epochs() == [3]
    assert eng.stats()["epoch_table_bytes"] == eng._table_bytes()
    with pytest.raises(knn.EpochError):
        eng.keep_epochs = 0

    # per-epoch provenance survives for the retained epoch
    assert eng.epoch_stats(3)["origin"] == "flush"
    assert eng.epoch_stats(3)["flush"]["staged"] > 0


def test_sharded_routing_table_is_the_indirection():
    """The sharded engine's ownership + epoch resolution go through the
    ShardRoutingTable: owner lookup matches the contiguous-range layout,
    and each retained epoch resolves to its own buffers per shard."""
    g, bn, objects, k = _setup()
    eng = knn.build_sharded_engine(bn, objects, k, shards=None)
    rt = eng.routing
    vs = np.arange(eng.n)
    assert np.array_equal(rt.owner(vs), np.minimum(vs // rt.shard_rows, rt.num_shards - 1))
    assert np.array_equal(rt.padded_rows(vs), eng._g_of_v)

    mset = set(int(o) for o in objects)
    _stage_mix(eng, mset, seed=21)
    eng.flush_updates()
    assert rt.epochs() == eng.retained_epochs()
    for e in rt.epochs():
        sb = rt.shard_buffers(e)
        assert sorted(sb) == list(range(rt.num_shards))
        for s, (dev, ids_buf, d_buf) in sb.items():
            assert ids_buf.shape == (rt.shard_rows + 1, k)
            assert d_buf.shape == (rt.shard_rows + 1, k)
    with pytest.raises(knn.EpochError):
        rt.buffers(-1)
