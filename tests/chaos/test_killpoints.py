"""Fault-injection harness: kill-at-any-point crash recovery.

Drives the engine's ``_checkpoint(phase)`` seam with a hook that raises a
``SimulatedKill`` at one injection site — after a journal append, mid
repair round, just before the epoch swap, just after it — then "reboots"
by loading a fresh engine from the last saved artifact plus the journal.
The property (ISSUE 6 acceptance): for EVERY site, on BOTH engines, the
recovered tables are byte-identical to an uncrashed twin that applied the
same updates, and indices_equivalent to a fresh scalar-oracle rebuild of
the final object set.

Why the twin and the oracle are separate assertions: the flush pipeline is
deterministic per batch, so recovery replaying the journal's flush
boundaries reproduces the uncrashed engine's tables exactly (array_equal);
the oracle rebuild may break distance ties differently, so that comparison
is the tie-tolerant ``indices_equivalent`` — the same split the seed
engine tests use.
"""
import jax
import numpy as np
import pytest

from repro import knn
from repro.core.reference import knn_index_cons_plus
from repro.graph.generators import pick_objects, road_network

PHASES = ["post-journal-append", "pre-swap", "mid-repair-round", "post-swap"]
ENGINES = ["scalar", "sharded"]


class SimulatedKill(Exception):
    """Raised by the chaos hook to model the process dying at this point."""


def _setup(grid=8, mu=0.2, k=4, seed=0):
    g = road_network(grid, grid, seed=seed)
    objects = pick_objects(g.n, mu, seed=seed)
    bn = knn.build_bngraph(g)
    return g, bn, objects, k


def _build(kind, bn, objects, k):
    if kind == "scalar":
        return knn.build_engine(bn, objects, k)
    return knn.build_sharded_engine(bn, objects, k, shards=None)


def _load(kind, path, bn, journal):
    shards = len(jax.devices()) if kind == "sharded" else None
    return knn.load_engine(path, bn=bn, shards=shards, journal=journal)


def _stage_mix(eng, mset, seed, count=5):
    """Deterministic update batch given (seed, mset state): random net
    inserts/deletes plus one explicit move, so every flush has a purge set
    (the move's source) and the repair rounds — hence the mid-repair-round
    site — always run."""
    knn.stage_random_updates(eng, mset, rng=seed, count=count)
    u = sorted(mset)[0]
    v = next(w for w in range(eng.n) if w not in mset)
    eng.stage_move(u, v)
    mset.discard(u)
    mset.add(v)


def _tables(eng):
    idx = eng.to_index()
    return idx.ids, idx.dists


@pytest.mark.parametrize("phase", PHASES)
@pytest.mark.parametrize("kind", ENGINES)
def test_kill_point_recovery(kind, phase, tmp_path):
    g, bn, objects, k = _setup()
    art, wal = str(tmp_path / "idx.npz"), str(tmp_path / "wal.bin")

    eng = _build(kind, bn, objects, k)
    mset = set(int(o) for o in objects)
    eng.save(art)
    eng.attach_journal(wal)

    _stage_mix(eng, mset, seed=1)  # committed segment: flushed before the kill
    eng.flush_updates()
    _stage_mix(eng, mset, seed=2)  # the batch the crash interrupts

    fired = []

    def hook(e, ph):
        if ph == phase:
            fired.append(ph)
            raise SimulatedKill(ph)

    eng.checkpoint_hook = hook
    if phase == "post-journal-append":
        # the kill lands between the fsync and the ack: the caller never
        # saw the stage call return, but the record is durable, so
        # recovery MUST apply it
        extra = next(w for w in range(eng.n) if w not in mset)
        with pytest.raises(SimulatedKill):
            eng.stage_insert(extra)
        mset.add(extra)
    else:
        with pytest.raises(SimulatedKill):
            eng.flush_updates()
    assert fired, f"phase {phase} never fired"
    eng.checkpoint_hook = None

    # -- reboot: fresh engine from the artifact + journal replay ---------
    rec = _load(kind, art, bn, wal)

    # -- uncrashed twin: same artifact, same updates, same flush fences --
    twin = _load(kind, art, bn, None)
    tset = set(int(o) for o in objects)
    _stage_mix(twin, tset, seed=1)
    twin.flush_updates()
    _stage_mix(twin, tset, seed=2)
    if phase == "post-journal-append":
        twin.stage_insert(extra)
        tset.add(extra)
    twin.flush_updates()
    assert tset == mset

    assert rec.epoch == twin.epoch
    assert np.array_equal(rec.objects, twin.objects)
    ri, rd = _tables(rec)
    ti, td = _tables(twin)
    assert np.array_equal(ri, ti) and np.array_equal(rd, td)

    # query surface, not just the raw tables
    us = np.arange(g.n, dtype=np.int32)
    qi_r, qd_r = rec.query_batch(us)
    qi_t, qd_t = twin.query_batch(us)
    assert np.array_equal(np.asarray(qi_r), np.asarray(qi_t))
    assert np.array_equal(np.asarray(qd_r), np.asarray(qd_t))

    # and the scalar-oracle ground truth (tie-tolerant)
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(fresh, rec.to_index())


@pytest.mark.parametrize("kind", ENGINES)
def test_failed_flush_rolls_back_and_is_retryable(kind):
    """A flush that dies before the swap leaves the engine serving epoch e
    with the staged queue intact; dropping the fault and flushing again
    succeeds — serving never stops and no update is lost."""
    g, bn, objects, k = _setup()
    eng = _build(kind, bn, objects, k)
    mset = set(int(o) for o in objects)
    us = np.arange(g.n, dtype=np.int32)
    before_i, before_d = eng.query_batch(us)
    epoch0 = eng.epoch

    _stage_mix(eng, mset, seed=3)
    depth = eng.queue_depth

    def hook(e, ph):
        if ph == "pre-swap":
            raise SimulatedKill(ph)

    eng.checkpoint_hook = hook
    with pytest.raises(SimulatedKill):
        eng.flush_updates()
    eng.checkpoint_hook = None

    assert eng.epoch == epoch0
    assert eng.queue_depth == depth
    assert eng.stats()["flushes_failed"] == 1
    mid_i, mid_d = eng.query_batch(us)
    assert np.array_equal(np.asarray(mid_i), np.asarray(before_i))
    assert np.array_equal(np.asarray(mid_d), np.asarray(before_d))

    stats = eng.flush_updates()  # retry, fault removed
    assert stats["staged"] == depth
    assert eng.epoch == epoch0 + 1
    fresh = knn_index_cons_plus(bn, np.array(sorted(mset)), k)
    assert knn.indices_equivalent(fresh, eng.to_index())


@pytest.mark.parametrize("kind", ENGINES)
@pytest.mark.parametrize("partial", [0, 1, 7])
def test_kill_at_journal_creation_recovers_fresh(kind, partial, tmp_path):
    """The kill site BEFORE every other one: between the journal file's
    creation and its magic fsync. The file on disk is 0-7 bytes of partial
    magic; no record — hence no acknowledged op — can exist behind it, so
    reboot must adopt it as a fresh journal and serve normally, not refuse
    to open. A FULL-length wrong magic is a different animal (someone
    else's file) and still raises."""
    g, bn, objects, k = _setup()
    art, wal = str(tmp_path / "idx.npz"), str(tmp_path / "wal.bin")
    eng = _build(kind, bn, objects, k)
    eng.save(art)
    with open(wal, "wb") as f:  # the kill left a torn magic behind
        f.write(b"RKNNWAL1"[:partial])

    rec = _load(kind, art, bn, wal)
    mset = set(int(o) for o in objects)
    _stage_mix(rec, mset, seed=4)
    rec.flush_updates()

    rec2 = _load(kind, art, bn, wal)  # the recovered journal replays clean
    assert rec2.epoch == rec.epoch
    ri, rd = _tables(rec)
    qi, qd = _tables(rec2)
    assert np.array_equal(ri, qi) and np.array_equal(rd, qd)

    bad = str(tmp_path / "notmine.bin")
    with open(bad, "wb") as f:
        f.write(b"SQLITEv3")  # full magic length, wrong bytes
    with pytest.raises(knn.JournalError):
        knn.UpdateJournal(bad)
