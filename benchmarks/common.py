"""Shared benchmark fixtures: synthetic road networks at benchmark scale,
timing helpers, CSV emission (one function per paper table; every row prints
``name,us_per_call,derived``)."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core.bngraph import build_bngraph
from repro.graph.generators import pick_objects, road_network

DEFAULT_GRID = 48  # n = 2304 — CPU-container scale; same trends as Table 2

# Machine-readable capture of everything row()/meta() emit, for --json output.
RESULTS: list[dict] = []
META: dict[str, object] = {}


def reset_results() -> None:
    RESULTS.clear()
    META.clear()


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    RESULTS.append({"name": name, "us_per_call": float(us_per_call), "derived": derived})


def meta(name: str, value) -> None:
    """Record a non-timing stat (occupancy, compile counts, ...) for --json."""
    META[name] = value


def time_us(fn, *, repeat: int = 3, number: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


@functools.lru_cache(maxsize=8)
def dataset(grid: int = DEFAULT_GRID, mu: float = 0.005, seed: int = 0):
    g = road_network(grid, grid, seed=seed)
    mu_eff = max(mu, 30.0 / g.n)  # keep |M| sensible at small n
    objects = pick_objects(g.n, mu_eff, seed=seed)
    return g, objects


@functools.lru_cache(maxsize=8)
def bngraph(grid: int = DEFAULT_GRID, seed: int = 0):
    g, _ = dataset(grid, seed=seed)
    return build_bngraph(g)


def query_vertices(n: int, count: int = 2000, seed: int = 1) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, n, size=count).astype(np.int64)
