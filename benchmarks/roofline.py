"""§Roofline: read the dry-run artifacts and emit the per-cell roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]

Terms (TPU v5e): compute = FLOPs/(197 TF/s), memory = bytes/(819 GB/s),
collective = coll_bytes/(50 GB/s link). All per-device (the partitioned HLO
reports per-device shapes). MODEL_FLOPS = 6*N(*_active)*D for LM cells.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.registry import get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_global(arch_id: str, shape: str) -> float | None:
    """6*N*D (dense) / 6*N_active*D (MoE) for LM train cells; None otherwise."""
    arch = get_arch(arch_id)
    if arch.family != "lm" or shape != "train_4k":
        return None
    cfg = arch.make_config()
    n = cfg.active_param_count()
    d = 256 * 4096
    return 6.0 * n * d


def load_records(d: Path) -> list[dict]:
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    return sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def render(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | bottleneck | "
        "MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = r["roofline_terms_s"]
        mf = model_flops_global(r["arch"], r["shape"])
        ratio = ""
        if mf is not None and r["per_device"]["flops"]:
            hlo_global = r["per_device"]["flops"] * r["n_chips"]
            ratio = f"{mf / hlo_global:.2f}"
        dom = max(t, key=t.get)
        frac = t[dom] / max(sum(t.values()), 1e-30)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {dom.replace('_s','')} "
            f"({frac:.0%}) | {ratio} | |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load_records(Path(args.dir))
    table = render(recs)
    print(table)
    if args.out:
        Path(args.out).write_text(table + "\n")


if __name__ == "__main__":
    main()
