"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only exp1,exp4] [--skip-kernels]
                                            [--json out/BENCH_cpu.json]
                                            [--devices 8]

Prints ``name,us_per_call,derived`` CSV rows. With ``--json PATH`` the same
rows plus the non-timing stats recorded via ``common.meta`` (sweep occupancy,
XLA compile counts, ...) are written as a machine-readable perf-trajectory
file so successive PRs can be diffed. ``--devices N`` forces N host CPU
devices (the multi-device grid exp13 sweeps) — it must take effect before
jax initializes, which is why it is a run.py flag and not something an
experiment can set for itself.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list, e.g. exp1,exp4")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as machine-readable JSON")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="force N host platform devices via XLA_FLAGS "
                         "(applied before jax import; exp13 then scales "
                         "across shard counts up to N)")
    args = ap.parse_args()

    if args.devices:
        if "jax" in sys.modules:
            raise SystemExit("--devices must be applied before jax initializes")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    from benchmarks import common, kernel_bench, paper_experiments

    fns = list(paper_experiments.ALL)
    if not args.skip_kernels:
        fns += kernel_bench.ALL
    if args.only:
        wanted = set(args.only.split(","))
        fns = [
            f
            for f in fns
            if f.__name__.split("_")[0] in wanted or f.__name__ in wanted
        ]

    common.reset_results()
    print("name,us_per_call,derived")
    t0 = time.time()
    status = "ok"
    try:
        for fn in fns:
            t1 = time.time()
            try:
                fn()
            except Exception as e:  # noqa: BLE001
                print(f"{fn.__name__},NaN,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
                raise
            print(f"# {fn.__name__} done in {time.time() - t1:.1f}s", file=sys.stderr)
    except Exception:
        status = "error"
        raise
    finally:
        total_s = time.time() - t0
        print(f"# total {total_s:.1f}s", file=sys.stderr)
        if args.json:
            import jax

            payload = {
                "status": status,
                "total_s": round(total_s, 3),
                "argv": sys.argv[1:],
                "platform": platform.platform(),
                "backend": jax.devices()[0].platform,
                "rows": common.RESULTS,
                "meta": common.META,
            }
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            print(f"# json written to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
