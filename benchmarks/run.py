"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only exp1,exp4] [--skip-kernels]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list, e.g. exp1,exp4")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_experiments

    fns = list(paper_experiments.ALL)
    if not args.skip_kernels:
        fns += kernel_bench.ALL
    if args.only:
        wanted = set(args.only.split(","))
        fns = [
            f
            for f in fns
            if f.__name__.split("_")[0] in wanted or f.__name__ in wanted
        ]

    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in fns:
        t1 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},NaN,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            raise
        print(f"# {fn.__name__} done in {time.time() - t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
