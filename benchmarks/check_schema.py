"""CI schema/floor assertions over a ``benchmarks.run --json`` file.

    python -m benchmarks.check_schema bench.json --require exp11 exp12
    python -m benchmarks.check_schema bench.json --require exp13 --min-devices 8

One checker per experiment family, shared by every CI job so the assertions
cannot drift between workflow legs. Each check validates the machine-readable
schema (the keys downstream perf-trajectory tooling diffs) AND the
experiment's acceptance floor:

* exp11 — engine serving stats present; batched path >= 5x the scalar loop.
* exp12 — fleet stats present; fused stage_move flushes >= 1.2x split.
* exp13 — per-device-count queries/s, ticks/s and row-padding overhead
  present for every measured device count; the sharded engine at ONE shard
  within >= 0.8x of the scalar engine on both metrics. ``--min-devices N``
  additionally demands the sweep actually reached N devices (the
  multi-device CI job passes 8, so a silently single-device run fails
  instead of skipping the scaling coverage).
* exp14 — host-frontier vs device-frontier flush throughput present for
  every batch size in every (scalar/sharded) x (host/device) cell; the
  scalar device-frontier pipeline >= 1.3x the host pipeline at batch 512.
* exp15 — mixed read/write serving: query p50/p99 present for both the
  between-flush and during-flush windows, with enough during-flush samples
  (the checkpoint probes actually fired inside every flush); the
  during-flush p99 within ``--exp15-ceiling`` (default 5x, measured
  ~1.6x) of the quiescent p99 — snapshot isolation means mid-flush
  queries read immutable epoch-e buffers, so the tail may not blow up.
* exp16 — replicated hot shard: unreplicated vs replicated queries/s on
  the zipf-skewed mix, bit-identical results, replica traffic actually
  served (replica_batches > 0, zero replica_errors). ``--min-devices 8``
  additionally demands the full 4-shard x3-replica layout ran and holds
  the replicated path >= 1.5x the unreplicated one (measured ~1.6-1.8x
  steady state).
* exp17 — traffic-balanced uneven shard ranges: equal-width vs
  repartitioned queries/s on the same zipf mix with ZERO replicas,
  bit-identical results across the repartition and to the scalar oracle,
  a valid boundary vector (starts at 0, strictly increasing, one per
  shard) and an improved balance ratio. ``--min-devices 8`` holds the
  uneven layout >= 1.3x equal-width queries/s.
* exp18 — collective halo exchange: host-halo vs collective-halo flush
  throughput present for every (shard count, staged batch) cell,
  bit-identical tables against the scalar oracle, collective rounds
  actually exchanged with zero capacity-overflow fallbacks.
  ``--min-devices 8`` demands the sweep reached 8 shards and holds the
  collective halo >= 1.2x the routed host halo at 8 shards, batch 512.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

EXP13_PARITY_FLOOR = 0.8
EXP14_DEVICE_FLOOR = 1.3
EXP15_P99_CEILING = 5.0
EXP16_SPEEDUP_FLOOR = 1.5
EXP17_SPEEDUP_FLOOR = 1.3
EXP18_SPEEDUP_FLOOR = 1.2


def _need(meta: dict, key: str):
    assert key in meta, f"missing {key} in bench meta"
    return meta[key]


def _compile_budgets() -> dict:
    """The checked-in warm/cold compile budgets (tools/compile_budgets.json).

    The warm counters the benchmarks publish are asserted EQUAL to these:
    a higher count is a recompile regression, a lower one means the budget
    file is stale and must be tightened.
    """
    p = Path(__file__).resolve().parent.parent / "tools" / "compile_budgets.json"
    with open(p) as f:
        return json.load(f)


def check_exp11(data: dict) -> str:
    meta = data["meta"]
    for key in ("exp11.engine.batch_size", "exp11.engine.queries_per_s",
                "exp11.engine.staged_queue_depth",
                "exp11.engine.speedup_vs_scalar"):
        _need(meta, key)
    stats = _need(meta, "exp11.engine.stats")
    for key in ("n", "k", "queries_served", "query_batches", "flushes",
                "staged_queue_depth"):
        assert key in stats, f"missing engine stat {key}"
    names = {r["name"] for r in data["rows"]}
    assert "exp11.serve.scalar_query_loop" in names
    assert any(n.startswith("exp11.serve.engine_query_batch.") for n in names)
    assert "exp11.serve.engine_mixed_bua" in names
    # acceptance floor: the batched path must stay an order of magnitude
    # ahead of the scalar loop (measured 17-32x; 5x absorbs runner noise)
    assert meta["exp11.engine.speedup_vs_scalar"] >= 5.0, meta
    # residency counters: the warm query path may not compile (budget
    # equality) and must do its uploads explicitly (at least one device_put)
    compiles = _need(meta, "exp11.engine.compiles")
    transfers = _need(meta, "exp11.engine.host_transfers")
    warm_budget = _compile_budgets()["query_batch"]["warm"]
    assert compiles == warm_budget, (
        f"exp11 warm query_batch compiled {compiles} programs; budget "
        f"requires exactly {warm_budget} (tools/compile_budgets.json)"
    )
    assert set(transfers) == {"h2d", "d2h"}, transfers
    assert transfers["h2d"] >= 1, f"no explicit uploads counted: {transfers}"
    return (f"exp11 OK: {meta['exp11.engine.queries_per_s']} q/s, "
            f"x{meta['exp11.engine.speedup_vs_scalar']} vs scalar, "
            f"warm compiles {compiles}")


def check_exp12(data: dict, floor: float) -> str:
    meta = data["meta"]
    for key in ("exp12.fleet.size", "exp12.fleet.ticks_per_s_fused",
                "exp12.fleet.ticks_per_s_split", "exp12.fleet.fused_speedup",
                "exp12.fleet.query_p50_us", "exp12.fleet.query_p99_us",
                "exp12.fleet.moves_per_tick"):
        _need(meta, key)
    fstats = _need(meta, "exp12.fleet.engine_stats")
    for key in ("moves_applied", "coalesced", "rows_repaired"):
        assert key in fstats, f"missing fleet engine stat {key}"
    # acceptance: fused stage_move flushes beat the split delete+insert
    # flushes (steady-state measured 2.8x; the floor absorbs runner noise —
    # the tier-1 job holds 1.5x, the x64 leg the default 1.2x)
    assert meta["exp12.fleet.fused_speedup"] >= floor, meta
    return (f"exp12 OK: {meta['exp12.fleet.ticks_per_s_fused']} ticks/s, "
            f"x{meta['exp12.fleet.fused_speedup']} vs split flushes")


def check_exp13(data: dict, min_devices: int | None) -> str:
    meta = data["meta"]
    devices = _need(meta, "exp13.devices")
    assert devices and devices[0] == 1, f"exp13 device counts start at 1: {devices}"
    if min_devices:
        assert max(devices) >= min_devices, (
            f"exp13 swept only {devices}; the multi-device job requires "
            f"{min_devices} (is XLA_FLAGS/--devices set?)"
        )
        # the grid must cover at least the prefix up to min_devices; a run
        # with even more devices visible is fine (it only extends the sweep)
        expect = [c for c in (1, 2, 4, 8) if c <= min_devices]
        assert devices[: len(expect)] == expect, (
            f"exp13 device grid {devices} does not cover {expect}"
        )
    for key in ("exp13.grid", "exp13.k", "exp13.query_batch_size",
                "exp13.plain.queries_per_s", "exp13.plain.ticks_per_s",
                "exp13.parity.queries_1shard_vs_plain",
                "exp13.parity.ticks_1shard_vs_plain"):
        _need(meta, key)
    qps = _need(meta, "exp13.shard.queries_per_s")
    ticks = _need(meta, "exp13.shard.ticks_per_s")
    pad = _need(meta, "exp13.shard.row_padding_overhead")
    names = {r["name"] for r in data["rows"]}
    for d in devices:
        for table in (qps, ticks, pad):
            assert str(d) in table, f"exp13 missing device count {d} in {table}"
        assert f"exp13.shard.d{d}.query_batch" in names
        assert f"exp13.shard.d{d}.fleet_tick" in names
    # acceptance floor: sharding may not tax the degenerate 1-shard case
    q_par = meta["exp13.parity.queries_1shard_vs_plain"]
    t_par = meta["exp13.parity.ticks_1shard_vs_plain"]
    assert q_par >= EXP13_PARITY_FLOOR, f"1-shard query parity {q_par} < 0.8x plain"
    assert t_par >= EXP13_PARITY_FLOOR, f"1-shard fleet parity {t_par} < 0.8x plain"
    return (f"exp13 OK: devices {devices}, 1-shard parity "
            f"q={q_par}x t={t_par}x, q/s per device {qps}")


def check_exp14(data: dict) -> str:
    meta = data["meta"]
    batches = _need(meta, "exp14.batch_sizes")
    assert batches == [8, 64, 512], f"exp14 batch grid {batches} != [8, 64, 512]"
    for key in ("exp14.grid", "exp14.k", "exp14.mu", "exp14.sharded.shards",
                "exp14.frontier_rounds", "exp14.device_speedup_b512"):
        _need(meta, key)
    names = {r["name"] for r in data["rows"]}
    for layout in ("scalar", "sharded"):
        for mode in ("host", "device"):
            table = _need(meta, f"exp14.{layout}.{mode}.inserts_per_s")
            for b in batches:
                assert str(b) in table, f"exp14 {layout}/{mode} missing b={b}"
                assert table[str(b)] > 0
                assert f"exp14.frontier.{layout}.{mode}.b{b}" in names
    # acceptance floor: at batch 512 the batched device relaxation must beat
    # the per-object host heap pipeline (measured ~4.7x; 1.3x absorbs
    # runner noise). Small batches may sit below 1x and are not floored.
    speedup = meta["exp14.device_speedup_b512"]
    assert speedup >= EXP14_DEVICE_FLOOR, (
        f"exp14 device frontier speedup {speedup} < {EXP14_DEVICE_FLOOR}x at b512"
    )
    # residency counters for the warm (rep-2) flush of every cell: compile
    # count must EQUAL the warm budget for the layout, and every flush does
    # at least one explicit host crossing (staged uploads / kth readbacks)
    comp = _need(meta, "exp14.compiles")
    trans = _need(meta, "exp14.host_transfers")
    budgets = _compile_budgets()
    for layout in ("scalar", "sharded"):
        key = "flush_updates" if layout == "scalar" else "sharded_flush_updates"
        warm_budget = budgets[key]["warm"]
        for mode in ("host", "device"):
            for b in batches:
                c = comp[layout][mode][str(b)]
                assert c == warm_budget, (
                    f"exp14 {layout}/{mode} b={b} warm flush compiled {c} "
                    f"programs; budget requires exactly {warm_budget} "
                    f"(tools/compile_budgets.json:{key})"
                )
                t = trans[layout][mode][str(b)]
                assert set(t) == {"h2d", "d2h"}, t
                assert t["h2d"] + t["d2h"] >= 1, (
                    f"exp14 {layout}/{mode} b={b} counted no explicit host "
                    f"crossings — the counters are not wired"
                )
    return (f"exp14 OK: device frontier x{speedup} vs host at b512, "
            f"{meta['exp14.scalar.device.inserts_per_s']['512']} ins/s, "
            f"warm compiles clean")


def check_exp15(data: dict, ceiling: float) -> str:
    meta = data["meta"]
    for key in ("exp15.grid", "exp15.k", "exp15.mu", "exp15.query_batch_size",
                "exp15.rounds", "exp15.between.samples", "exp15.during.samples",
                "exp15.between.query_p50_us", "exp15.between.query_p99_us",
                "exp15.during.query_p50_us", "exp15.during.query_p99_us",
                "exp15.p99_degradation_x", "exp15.flush_p50_us",
                "exp15.engine.epoch"):
        _need(meta, key)
    names = {r["name"] for r in data["rows"]}
    for name in ("exp15.mixed_rw.query_between", "exp15.mixed_rw.query_during",
                 "exp15.mixed_rw.flush"):
        assert name in names, f"missing row {name}"
    # the probes must actually have fired INSIDE every flush (>= 3 sites per
    # flush: mid-repair-round, pre-swap, post-swap), else "during" is vacuous
    rounds = meta["exp15.rounds"]
    assert meta["exp15.during.samples"] >= 3 * rounds, (
        f"only {meta['exp15.during.samples']} during-flush probes over "
        f"{rounds} flushes — checkpoint sites did not all fire"
    )
    assert meta["exp15.engine.epoch"] >= rounds  # every flush swapped an epoch
    # acceptance ceiling: snapshot isolation keeps mid-flush reads on the
    # immutable epoch-e buffers, so the during-flush tail may pay queue
    # contention but not table-rebuild stalls (measured ~1.6x)
    deg = meta["exp15.p99_degradation_x"]
    assert deg <= ceiling, (
        f"exp15 during-flush p99 degradation {deg}x > {ceiling}x ceiling"
    )
    return (f"exp15 OK: p99 {meta['exp15.during.query_p99_us']}us during vs "
            f"{meta['exp15.between.query_p99_us']}us between flushes "
            f"(x{deg} <= {ceiling}x)")


def check_exp16(data: dict, min_devices: int | None) -> str:
    meta = data["meta"]
    for key in ("exp16.grid", "exp16.k", "exp16.query_batch_size",
                "exp16.devices", "exp16.shards", "exp16.zipf_theta",
                "exp16.hot_shard", "exp16.hot_frac", "exp16.replicas",
                "exp16.identical_results", "exp16.qps.unreplicated",
                "exp16.qps.replicated", "exp16.speedup",
                "exp16.engine.replica_queries", "exp16.engine.replica_batches",
                "exp16.engine.replica_errors"):
        _need(meta, key)
    names = {r["name"] for r in data["rows"]}
    for name in ("exp16.hot.unreplicated", "exp16.hot.replicated"):
        assert name in names, f"missing row {name}"
    assert meta["exp16.identical_results"] is True, (
        "exp16 replicated results were not bit-identical to unreplicated"
    )
    assert meta["exp16.hot_frac"] >= 0.8, (
        f"exp16 zipf mix concentrated only {meta['exp16.hot_frac']} on the "
        f"hot shard — the skew the experiment is about is missing"
    )
    assert meta["exp16.engine.replica_errors"] == 0, meta
    if meta["exp16.replicas"]:
        assert meta["exp16.engine.replica_batches"] > 0, (
            "exp16 ran with replicas but no batch was served through the "
            "replica fan-out path"
        )
        assert meta["exp16.engine.replica_queries"] > 0, meta
    if min_devices and min_devices >= 8:
        assert meta["exp16.devices"] >= 8, (
            f"exp16 saw only {meta['exp16.devices']} devices; the "
            f"multi-device job requires 8 (is XLA_FLAGS/--devices set?)"
        )
        assert meta["exp16.shards"] == 4 and meta["exp16.replicas"] == 3, (
            f"exp16 layout {meta['exp16.shards']} shards x "
            f"{meta['exp16.replicas']} replicas != the 4x3 acceptance layout"
        )
        # acceptance floor: fanning the hot shard across its replica set
        # must actually buy throughput on the skewed mix
        sp = meta["exp16.speedup"]
        assert sp >= EXP16_SPEEDUP_FLOOR, (
            f"exp16 replicated speedup {sp}x < {EXP16_SPEEDUP_FLOOR}x floor"
        )
    return (f"exp16 OK: x{meta['exp16.speedup']} replicated vs unreplicated "
            f"(hot_frac {meta['exp16.hot_frac']}, "
            f"{meta['exp16.shards']}shards x{meta['exp16.replicas']}replicas, "
            f"{meta['exp16.engine.replica_queries']} replica queries, "
            f"0 errors)")


def check_exp17(data: dict, min_devices: int | None) -> str:
    meta = data["meta"]
    for key in ("exp17.grid", "exp17.k", "exp17.query_batch_size",
                "exp17.devices", "exp17.shards", "exp17.zipf_theta",
                "exp17.replicas", "exp17.boundaries", "exp17.balance.equal",
                "exp17.balance.uneven", "exp17.identical_results",
                "exp17.qps.equal", "exp17.qps.uneven", "exp17.speedup",
                "exp17.engine.repartitions"):
        _need(meta, key)
    names = {r["name"] for r in data["rows"]}
    for name in ("exp17.ranges.equal", "exp17.ranges.uneven"):
        assert name in names, f"missing row {name}"
    assert meta["exp17.identical_results"] is True, (
        "exp17 uneven-range results were not bit-identical to equal-width "
        "and the scalar oracle"
    )
    # the whole point is beating the hot shard WITHOUT replica devices
    assert meta["exp17.replicas"] == 0, (
        f"exp17 ran with {meta['exp17.replicas']} replicas — the uneven-"
        f"range comparison must spend zero extra devices"
    )
    shards = meta["exp17.shards"]
    starts = meta["exp17.boundaries"]
    assert len(starts) == shards, (
        f"exp17 boundary vector {starts} does not name {shards} shards"
    )
    assert starts[0] == 0 and all(
        b > a for a, b in zip(starts, starts[1:])
    ), f"exp17 boundary vector {starts} is not sorted starting at 0"
    assert meta["exp17.engine.repartitions"] >= 1, (
        "exp17 never exercised repartition-on-flush"
    )
    # the splitter must have actually flattened the traffic skew
    assert meta["exp17.balance.uneven"] < meta["exp17.balance.equal"], (
        f"exp17 balance ratio did not improve: equal "
        f"{meta['exp17.balance.equal']} vs uneven {meta['exp17.balance.uneven']}"
    )
    if min_devices and min_devices >= 8:
        assert meta["exp17.devices"] >= 8, (
            f"exp17 saw only {meta['exp17.devices']} devices; the "
            f"multi-device job requires 8 (is XLA_FLAGS/--devices set?)"
        )
        assert shards == 4, (
            f"exp17 ran {shards} shards != the 4-shard acceptance layout"
        )
        # acceptance floor: traffic-balanced boundaries must buy real
        # throughput on the skewed mix with NO extra devices
        sp = meta["exp17.speedup"]
        assert sp >= EXP17_SPEEDUP_FLOOR, (
            f"exp17 uneven-range speedup {sp}x < {EXP17_SPEEDUP_FLOOR}x floor"
        )
    return (f"exp17 OK: x{meta['exp17.speedup']} uneven vs equal-width "
            f"(balance {meta['exp17.balance.equal']} -> "
            f"{meta['exp17.balance.uneven']}, boundaries {starts}, "
            f"0 replicas)")


def check_exp18(data: dict, min_devices: int | None) -> str:
    meta = data["meta"]
    for key in ("exp18.grid", "exp18.k", "exp18.mu", "exp18.batch_sizes",
                "exp18.devices", "exp18.shard_counts", "exp18.inserts_per_s",
                "exp18.collective_rounds", "exp18.identical_results",
                "exp18.speedup_b512"):
        _need(meta, key)
    batches = meta["exp18.batch_sizes"]
    assert batches == [64, 512], f"exp18 batch grid {batches} != [64, 512]"
    counts = meta["exp18.shard_counts"]
    assert counts, "exp18 measured no multi-shard counts"
    names = {r["name"] for r in data["rows"]}
    per_s = meta["exp18.inserts_per_s"]
    rounds = meta["exp18.collective_rounds"]
    for d in counts:
        for mode in ("host", "collective"):
            table = per_s[str(d)][mode]
            for b in batches:
                assert str(b) in table and table[str(b)] > 0, (
                    f"exp18 d={d}/{mode} missing b={b}"
                )
                assert f"exp18.halo.d{d}.{mode}.b{b}" in names
        for b in batches:
            # the collective leg really exchanged halos on device (a run
            # that silently fell back to the routed path measures nothing)
            assert rounds[f"d{d}.b{b}"] > 0, (
                f"exp18 d={d} b={b} ran zero collective halo rounds"
            )
    assert meta["exp18.identical_results"] is True, (
        "exp18 halo tables were not bit-identical to the scalar oracle"
    )
    if min_devices and min_devices >= 8:
        assert meta["exp18.devices"] >= 8, (
            f"exp18 saw only {meta['exp18.devices']} devices; the "
            f"multi-device job requires 8 (is XLA_FLAGS/--devices set?)"
        )
        assert 8 in counts, f"exp18 sweep {counts} never reached 8 shards"
        sp = meta["exp18.speedup_b512"]
        assert sp >= EXP18_SPEEDUP_FLOOR, (
            f"exp18 collective halo speedup {sp}x < "
            f"{EXP18_SPEEDUP_FLOOR}x floor at 8 shards/b512"
        )
    return (f"exp18 OK: x{meta['exp18.speedup_b512']} collective vs host "
            f"halo at d{counts[-1]}/b512, shard counts {counts}, "
            f"bit-identical")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--require", nargs="+", required=True,
                    choices=("exp11", "exp12", "exp13", "exp14", "exp15",
                             "exp16", "exp17", "exp18"))
    ap.add_argument("--min-devices", type=int, default=None,
                    help="exp13: demand the sweep reached this device count")
    ap.add_argument("--exp12-floor", type=float, default=1.2,
                    help="exp12 fused-speedup acceptance floor")
    ap.add_argument("--exp15-ceiling", type=float, default=EXP15_P99_CEILING,
                    help="exp15 during-flush p99 degradation ceiling")
    args = ap.parse_args()

    with open(args.json_path) as f:
        data = json.load(f)
    assert data.get("status") == "ok", f"bench run status={data.get('status')}"

    for exp in args.require:
        if exp == "exp11":
            print(check_exp11(data))
        elif exp == "exp12":
            print(check_exp12(data, args.exp12_floor))
        elif exp == "exp13":
            print(check_exp13(data, args.min_devices))
        elif exp == "exp14":
            print(check_exp14(data))
        elif exp == "exp15":
            print(check_exp15(data, args.exp15_ceiling))
        elif exp == "exp16":
            print(check_exp16(data, args.min_devices))
        elif exp == "exp17":
            print(check_exp17(data, args.min_devices))
        else:
            print(check_exp18(data, args.min_devices))
    print(f"schema OK: {args.json_path} ({', '.join(args.require)})",
          file=sys.stderr)


if __name__ == "__main__":
    main()
