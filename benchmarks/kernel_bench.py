"""Kernel micro-benchmarks (CPU wall time is NOT the roofline — interpret
mode / XLA-CPU; these check functional throughput trends and feed §Perf with
candidate-vs-candidate ratios that carry to TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_us
from repro.kernels import ops


def bench_topk_merge() -> None:
    rng = np.random.default_rng(0)
    for b, c, k in [(1024, 256, 20), (4096, 128, 10)]:
        ids = jnp.asarray(rng.integers(0, 5000, (b, c)), jnp.int32)
        d = jnp.asarray(rng.uniform(0, 100, (b, c)), jnp.float32)
        out = ops.topk_merge(ids, d, k, use_pallas=False)
        jax.block_until_ready(out)
        t = time_us(lambda: jax.block_until_ready(ops.topk_merge(ids, d, k, use_pallas=False)))
        row(f"kernel.topk_merge.xla.b{b}c{c}k{k}", t, f"{b * c / t:.0f}cand/us")


def bench_retrieval_topk() -> None:
    rng = np.random.default_rng(0)
    for b, n, k in [(8, 262144, 100), (1, 1048576, 100)]:
        s = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
        out = ops.retrieval_topk(s, k, use_pallas=False)
        jax.block_until_ready(out)
        t = time_us(lambda: jax.block_until_ready(ops.retrieval_topk(s, k, use_pallas=False)))
        row(f"kernel.retrieval_topk.xla.b{b}n{n}", t, f"{b * n * 4 / t:.0f}B/us")


def bench_minplus() -> None:
    rng = np.random.default_rng(0)
    for m in (256, 512):
        a = jnp.asarray(rng.uniform(0, 10, (m, m)), jnp.float32)
        b = jnp.asarray(rng.uniform(0, 10, (m, m)), jnp.float32)
        out = ops.minplus_matmul(a, b, use_pallas=False)
        jax.block_until_ready(out)
        t = time_us(lambda: jax.block_until_ready(ops.minplus_matmul(a, b, use_pallas=False)))
        row(f"kernel.minplus.xla.m{m}", t, f"{2 * m**3 / t / 1e6:.2f}Gop/s")


ALL = [bench_topk_merge, bench_retrieval_topk, bench_minplus]
