"""Reproductions of the paper's Exp-1 ... Exp-10 at container scale.

Every function mirrors one figure/table; rows print ``name,us_per_call,derived``.
Claims validated (paper §7):
  Exp-1  KNN-Index query is O(k), ~2 orders below TEN / Dijkstra, flat growth
  Exp-2  KNN-Index query time independent of object density mu
  Exp-3  progressive output: i-th result in O(i)
  Exp-4  Cons+ >> Cons >> Dijkstra-Cons / TEN-Cons construction time
  Exp-5  index size: KNN-Index ~ n*k entries, TEN dominated by H2H labels
  Exp-6  indexing time/size grow mildly with k
  Exp-7  scalability in n
  Exp-8  update (insert/delete) cost — the paper's known weak spot
  Exp-9  throughput under BUA+QF and RUA+FCFS mixes
  Exp-10 min-degree order >> degree/id static orders

Beyond the paper (this repo's serving surface):
  Exp-11 batched QueryEngine serving vs the scalar per-call loop
  Exp-12 moving-fleet workload: fused stage_move flushes vs split
         delete+insert flushes on the same movement trace
  Exp-13 vertex-sharded multi-device engine: queries/s and fleet ticks/s
         per device count (forced host devices), vs the scalar engine
  Exp-14 batched device checkIns frontier: flush throughput vs staged-insert
         batch size, host-frontier vs device-frontier, scalar and sharded
  Exp-15 mixed read/write serving: query p50/p99 sampled DURING flushes
         (from inside the pipeline, via the checkpoint hook) vs between
         them — the snapshot-isolation tail-latency experiment
  Exp-16 replicated hot shard: zipf-skewed query mix served unreplicated
         vs with the hot shard fanned out over a replica set — the
         shard->replicas routing-table experiment
  Exp-17 traffic-balanced uneven shard ranges vs equal-width boundaries
         on the same zipf mix, zero extra devices (repartition-on-flush)
  Exp-18 collective all_gather halo exchange vs the routed host halo:
         flush throughput per shard count and staged batch, device-
         resident cross-shard repair/frontier rows
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    DEFAULT_GRID,
    bngraph,
    dataset,
    meta,
    query_vertices,
    row,
    time_us,
)
from repro.core.baselines import TENIndexLite
from repro.core.bngraph import build_bngraph
from repro.core.construct_jax import build_knn_index_jax
from repro.core.reference import (
    dijkstra_cons,
    dijkstra_knn,
    knn_index_cons,
    knn_index_cons_plus,
)
from repro.core.updates import delete_object, insert_object
from repro.graph.generators import pick_objects, road_network


def _build(k: int, grid: int = DEFAULT_GRID, mu: float = 0.005):
    g, objects = dataset(grid, mu)
    bn = bngraph(grid)
    idx = knn_index_cons_plus(bn, objects, k)
    return g, objects, bn, idx


def exp1_query_vs_k() -> None:
    g, objects, bn, _ = _build(10)
    is_obj = np.zeros(g.n, bool)
    is_obj[objects] = True
    ten = TENIndexLite(g, objects, 100)
    qs = query_vertices(g.n, 400)
    for k in (10, 20, 40, 60, 100):
        idx = knn_index_cons_plus(bn, objects, k)
        t_knn = time_us(lambda: [idx.query(int(u), k) for u in qs]) / len(qs)
        t_ten = time_us(lambda: [ten.knn(int(u), k) for u in qs], repeat=1) / len(qs)
        t_dij = time_us(
            lambda: [dijkstra_knn(g, is_obj, k, int(u)) for u in qs[:40]], repeat=1
        ) / 40
        row(f"exp1.query.k{k}.knn_index", t_knn, f"k={k}")
        row(f"exp1.query.k{k}.ten_lite", t_ten, f"k={k};x{t_ten / max(t_knn, 1e-9):.0f}")
        row(f"exp1.query.k{k}.dijkstra", t_dij, f"k={k};x{t_dij / max(t_knn, 1e-9):.0f}")


def exp2_query_vs_mu() -> None:
    k = 20
    g, _, bn, _ = _build(k)
    qs = query_vertices(g.n, 400)
    for mu in (0.05, 0.02, 0.01, 0.005):
        objects = pick_objects(g.n, mu, seed=0)
        if len(objects) <= k:
            continue
        idx = knn_index_cons_plus(bn, objects, k)
        is_obj = np.zeros(g.n, bool)
        is_obj[objects] = True
        t_knn = time_us(lambda: [idx.query(int(u)) for u in qs]) / len(qs)
        t_dij = time_us(
            lambda: [dijkstra_knn(g, is_obj, k, int(u)) for u in qs[:40]], repeat=1
        ) / 40
        row(f"exp2.query.mu{mu}.knn_index", t_knn, f"mu={mu}")
        row(f"exp2.query.mu{mu}.dijkstra", t_dij, f"mu={mu};x{t_dij / max(t_knn, 1e-9):.0f}")


def exp3_progressive() -> None:
    k = 60
    g, objects, bn, idx = _build(k)
    qs = query_vertices(g.n, 200)
    for i in (5, 15, 30, 45, 60):
        def first_i():
            for u in qs:
                out = []
                for item in idx.query_progressive(int(u)):
                    out.append(item)
                    if len(out) >= i:
                        break
        t = time_us(first_i) / len(qs)
        row(f"exp3.progressive.first{i}", t, f"i={i}")


def exp4_indexing_time() -> None:
    k = 20
    g, objects = dataset()
    t0 = time.perf_counter()
    bn = build_bngraph(g)
    t_bn = time.perf_counter() - t0

    t0 = time.perf_counter()
    knn_index_cons_plus(bn, objects, k)
    t_plus = time.perf_counter() - t0
    row("exp4.cons.knn_index_cons_plus", (t_bn + t_plus) * 1e6, "alg3(bidirectional)")

    t0 = time.perf_counter()
    knn_index_cons(bn, objects, k)
    t_cons = time.perf_counter() - t0
    row("exp4.cons.knn_index_cons", (t_bn + t_cons) * 1e6,
        f"alg2(bottom-up);x{(t_bn + t_cons) / (t_bn + t_plus):.1f}")

    from repro.core import construct_jax

    compiles_before = construct_jax.sweep_compile_count()
    t0 = time.perf_counter()
    build_knn_index_jax(bn, objects, k, use_pallas=False)
    t_jax_cold = time.perf_counter() - t0
    compiles = (
        construct_jax.sweep_compile_count() - compiles_before
        if compiles_before >= 0
        else "n/a"
    )
    row("exp4.cons.jax_fused_sweeps_cold", (t_bn + t_jax_cold) * 1e6,
        f"device sweeps incl compile;xla_programs={compiles}")
    t0 = time.perf_counter()
    build_knn_index_jax(bn, objects, k, use_pallas=False)
    t_jax = time.perf_counter() - t0
    row("exp4.cons.jax_fused_sweeps", (t_bn + t_jax) * 1e6, "device sweeps (CPU backend)")
    for direction in ("up", "down"):
        plan = construct_jax.prepare_sweep(bn, direction)
        meta(f"exp4.sweep.{direction}.occupancy", round(plan.occupancy, 4))
        meta(f"exp4.sweep.{direction}.occupancy_levelwise",
             round(plan.occupancy_levelwise, 4))
        meta(f"exp4.sweep.{direction}.levels", plan.num_levels)
        meta(f"exp4.sweep.{direction}.chunks", plan.num_chunks)
        meta(f"exp4.sweep.{direction}.shape_buckets", len(plan.buckets))
    meta("exp4.sweep.xla_programs_per_build", compiles)

    t0 = time.perf_counter()
    dijkstra_cons(g, objects, k)
    t_dij = time.perf_counter() - t0
    row("exp4.cons.dijkstra_cons", t_dij * 1e6, f"x{t_dij / (t_bn + t_plus):.1f}")

    t0 = time.perf_counter()
    ten = TENIndexLite(g, objects, k)
    t_ten_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    ten.build_knn_index()
    t_ten_cons = time.perf_counter() - t0
    row("exp4.cons.ten_index", t_ten_build * 1e6,
        f"h2h-dominated;x{t_ten_build / (t_bn + t_plus):.1f}")
    row("exp4.cons.ten_index_cons", (t_ten_build + t_ten_cons) * 1e6,
        "KNN-Index built via TEN queries")


def exp5_index_size() -> None:
    k = 20
    g, objects, bn, idx = _build(k)
    ten = TENIndexLite(g, objects, k)
    knn_b = idx.size_bytes(dist_bytes=4)  # the paper's n*k*(4+4) count
    ten_b = ten.size_bytes()
    row("exp5.size.knn_index_bytes", knn_b, f"n*k*8={g.n}*{k}*8")
    row("exp5.size.ten_lite_bytes", ten_b, f"x{ten_b / knn_b:.1f};h2h={ten.size_entries()['h2h_entries']}ent")


def exp6_vary_k_build() -> None:
    g, objects = dataset()
    bn = bngraph()
    for k in (10, 20, 40, 60, 100):
        t0 = time.perf_counter()
        idx = knn_index_cons_plus(bn, objects, k)
        dt = time.perf_counter() - t0
        row(f"exp6.build.k{k}", dt * 1e6, f"size={idx.size_bytes(dist_bytes=4)}B")


def exp7_scalability() -> None:
    k = 20
    for grid in (24, 32, 48, 64):
        g = road_network(grid, grid, seed=0)
        objects = pick_objects(g.n, 0.01, seed=0)
        t0 = time.perf_counter()
        bn = build_bngraph(g)
        knn_index_cons_plus(bn, objects, k)
        dt = time.perf_counter() - t0
        row(f"exp7.scale.n{g.n}", dt * 1e6, f"n={g.n};m={g.m}")


def exp8_updates() -> None:
    k = 20
    g, objects, bn, idx = _build(k)
    rng = np.random.default_rng(0)
    mset = set(objects.tolist())
    ins_t, del_t, n_ins, n_del = 0.0, 0.0, 0, 0
    for _ in range(300):
        u = int(rng.integers(0, g.n))
        if u in mset:
            if len(mset) <= k + 1:
                continue
            t0 = time.perf_counter()
            delete_object(bn, idx, u)
            del_t += time.perf_counter() - t0
            n_del += 1
            mset.discard(u)
        else:
            t0 = time.perf_counter()
            insert_object(bn, idx, u)
            ins_t += time.perf_counter() - t0
            n_ins += 1
            mset.add(u)
    row("exp8.update.insert", ins_t / max(n_ins, 1) * 1e6, f"n={n_ins}")
    row("exp8.update.delete", del_t / max(n_del, 1) * 1e6, f"n={n_del}")


def exp9_throughput() -> None:
    """BUA+QF: batched updates arrive, queries first. RUA+FCFS: random mix.
    Both arrival models replay the IDENTICAL update sequence (deletes cost
    ~7x inserts, so differing sequences would swamp the arrival effect)."""
    k = 20
    g, objects, bn, idx0 = _build(k)
    rng = np.random.default_rng(0)
    qs = query_vertices(g.n, 2000)
    n_updates = 50

    # one fixed update script, derived against a simulated object set
    sim = set(objects.tolist())
    script: list[tuple[int, str]] = []
    while len(script) < n_updates:
        u = int(rng.integers(0, g.n))
        if u in sim:
            if len(sim) <= k + 1:
                continue
            script.append((u, "del"))
            sim.discard(u)
        else:
            script.append((u, "ins"))
            sim.add(u)

    def apply_update(idx, u, op):
        if op == "del":
            delete_object(bn, idx, u)
        else:
            insert_object(bn, idx, u)

    # BUA + QF: serve all queries, then apply the update batch
    idx = idx0.copy()
    t0 = time.perf_counter()
    for u in qs:
        idx.query(int(u))
    for u, op in script:
        apply_update(idx, u, op)
    dt = time.perf_counter() - t0
    row("exp9.throughput.bua_qf", dt / (len(qs) + n_updates) * 1e6,
        f"{(len(qs) + n_updates) / dt:.0f}ops/s")

    # RUA + FCFS: same script interleaved 1 update per 40 queries
    idx = idx0.copy()
    t0 = time.perf_counter()
    ups = 0
    for i, u in enumerate(qs):
        idx.query(int(u))
        if i % 40 == 39 and ups < n_updates:
            apply_update(idx, *script[ups])
            ups += 1
    dt = time.perf_counter() - t0
    row("exp9.throughput.rua_fcfs", dt / (len(qs) + ups) * 1e6,
        f"{(len(qs) + ups) / dt:.0f}ops/s")


def exp11_engine_serving() -> None:
    """Batched QueryEngine serving vs the scalar per-call Python loop.

    The ISSUE-2 acceptance experiment (grid=40, k=20, CPU backend): mirrors
    Exp-2's query cost and Exp-9's mixed query+update traffic, but through
    the device-resident ``repro.knn`` serving path. Emits the engine stats
    (batch size, queries/s, staged-queue depth) as meta for the CI schema
    check; the engine batch path must report >= 10x the scalar loop's ops/s.
    """
    import jax

    from repro import knn

    k = 20
    g = road_network(40, 40, seed=0)
    objects = pick_objects(g.n, 0.02, seed=0)
    bn = build_bngraph(g)
    engine = knn.QueryEngine.build(bn, objects, k)
    idx = engine.to_index()
    rng = np.random.default_rng(1)

    # scalar baseline: one Python KNNIndex.query per op
    qs = rng.integers(0, g.n, size=4000)
    t0 = time.perf_counter()
    for u in qs:
        idx.query(int(u))
    t_scalar = time.perf_counter() - t0
    scalar_qps = len(qs) / t_scalar
    row("exp11.serve.scalar_query_loop", t_scalar / len(qs) * 1e6,
        f"{scalar_qps:.0f}ops/s")

    # engine: batched gather path at serving batch sizes
    best_qps, best_b = 0.0, 0
    for b in (512, 4096):
        us = rng.integers(0, g.n, size=b)
        jax.block_until_ready(engine.query_batch(us)[0])  # compile outside timing
        t0 = time.perf_counter()
        n = 0
        while time.perf_counter() - t0 < 1.0:
            ids, _ = engine.query_batch(us)
            jax.block_until_ready(ids)
            n += b
        qps = n / (time.perf_counter() - t0)
        if qps > best_qps:
            best_qps, best_b = qps, b
        row(f"exp11.serve.engine_query_batch.b{b}", 1e6 / qps,
            f"{qps:.0f}ops/s;x{qps / scalar_qps:.1f}")

    # mixed traffic: query tiles + staged updates flushed per tile (BUA)
    mset = set(engine.objects.tolist())
    batch, n_upd = 512, 26
    jax.block_until_ready(engine.query_batch(rng.integers(0, g.n, size=batch))[0])
    depth = 0
    t0 = time.perf_counter()
    ops_done = 0
    for _ in range(6):
        ids, _ = engine.query_batch(rng.integers(0, g.n, size=batch))
        jax.block_until_ready(ids)
        staged = knn.stage_random_updates(engine, mset, rng, n_upd)
        depth = max(depth, engine.queue_depth)
        engine.flush_updates()
        ops_done += batch + staged
    dt = time.perf_counter() - t0
    row("exp11.serve.engine_mixed_bua", dt / ops_done * 1e6,
        f"{ops_done / dt:.0f}ops/s;{n_upd}/{batch}upd")

    # warm-path residency counters: one already-compiled batch through the
    # sanitizer's counters. `compiles` is asserted EQUAL to the
    # tools/compile_budgets.json warm budget by check_schema (a warm query
    # that compiles is a recompile regression); host_transfers documents
    # the explicit h2d/d2h crossings per batch.
    from repro.analysis import sanitize

    us = rng.integers(0, g.n, size=best_b)
    jax.block_until_ready(engine.query_batch(us)[0])
    with sanitize.count_compiles() as cc, sanitize.count_transfers() as tc:
        ids, _ = engine.query_batch(us)
        jax.block_until_ready(ids)
    row("exp11.serve.engine_query_batch.warm_counters", 0.0,
        f"c{cc.count};h2d{tc.h2d};d2h{tc.d2h}")

    meta("exp11.engine.batch_size", best_b)
    meta("exp11.engine.queries_per_s", round(best_qps, 1))
    meta("exp11.engine.staged_queue_depth", depth)
    meta("exp11.engine.speedup_vs_scalar", round(best_qps / scalar_qps, 2))
    meta("exp11.engine.stats", engine.stats())
    meta("exp11.engine.compiles", cc.count)
    meta("exp11.engine.host_transfers", {"h2d": tc.h2d, "d2h": tc.d2h})


def exp12_moving_fleet() -> None:
    """Moving-objects serving: fused ``stage_move`` flushes vs split flushes.

    A ``FleetSim`` drives vehicles along shortest-path trips (the
    location-based-service workload: update traffic dominated by movement).
    The SAME movement trace is replayed through two engine strategies:

      fused — every (src, dst) staged via ``stage_move`` and flushed once per
          tick: one purge + checkIns frontier + ``rows_purge_merge`` pass,
          destination entries in the tables before the repair rounds start;
      split — the same trace staged as a delete flush then an insert flush
          per tick (the pre-move serving pattern, two full pipelines).

    Reports sustained ticks/s for both, the fused speedup (acceptance floor
    1.5x), and query p50/p99 while the flushes interleave with serving.
    """
    from repro import knn
    from repro.workloads import drive_fleet_ticks

    k = 10
    grid, fleet_size, n_ticks, batch = 32, 96, 24, 256
    g = road_network(grid, grid, seed=0)
    bn = build_bngraph(g)
    sim = knn.FleetSim(g, fleet_size=fleet_size, seed=0)
    init = sim.positions.copy()
    trace = [sim.tick() for _ in range(n_ticks)]

    def run(fused: bool):
        engine = knn.QueryEngine.build(bn, init, k)
        rng = np.random.default_rng(1)
        r = drive_fleet_ticks(engine, trace, batch=batch, rng=rng, split=not fused)
        return r["wall_s"], engine, r["lat"]

    # untimed warmup replays: each pipeline compiles its own flush/repair
    # shape-bucket programs, so the timed runs below measure steady state
    # (not whichever mode happens to run first paying the shared compiles)
    run(fused=True)
    run(fused=False)
    t_fused, eng_fused, lat = run(fused=True)
    t_split, eng_split, _ = run(fused=False)
    assert knn.indices_equivalent(eng_fused.to_index(), eng_split.to_index())

    ticks_fused = n_ticks / t_fused
    ticks_split = n_ticks / t_split
    p50 = float(np.percentile(lat, 50) * 1e6)
    p99 = float(np.percentile(lat, 99) * 1e6)
    moves_per_tick = sim.moves_total / n_ticks
    row("exp12.fleet.fused_tick", t_fused / n_ticks * 1e6,
        f"{ticks_fused:.2f}ticks/s;{moves_per_tick:.0f}moves/tick")
    row("exp12.fleet.split_tick", t_split / n_ticks * 1e6,
        f"{ticks_split:.2f}ticks/s;x{ticks_fused / ticks_split:.2f}fused")
    row("exp12.fleet.query_p50", p50, f"p99={p99:.0f}us;B={batch}")
    meta("exp12.fleet.size", fleet_size)
    meta("exp12.fleet.moves_per_tick", round(moves_per_tick, 1))
    meta("exp12.fleet.ticks_per_s_fused", round(ticks_fused, 2))
    meta("exp12.fleet.ticks_per_s_split", round(ticks_split, 2))
    meta("exp12.fleet.fused_speedup", round(ticks_fused / ticks_split, 2))
    meta("exp12.fleet.query_p50_us", round(p50, 1))
    meta("exp12.fleet.query_p99_us", round(p99, 1))
    meta("exp12.fleet.sim", sim.stats())
    meta("exp12.fleet.engine_stats", eng_fused.stats())


def exp13_sharded_scaling() -> None:
    """Vertex-sharded multi-device serving scaling (the ISSUE-4 acceptance).

    grid=48, k=10; for every device count in {1, 2, 4, 8} that the visible
    pool allows (CPU: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    or ``benchmarks.run --devices 8`` exposes all four), builds a
    ``ShardedQueryEngine`` at that many shards and measures batched
    queries/s plus moving-fleet ticks/s on the same movement trace the
    scalar engine serves. Parity floor: the sharded engine at ONE shard must
    stay within 0.8x of the scalar engine on both metrics (the partitioned
    layout may not tax the degenerate case). Each per-device row carries the
    shard layout's row-padding overhead so the scaling numbers are honest
    about the memory cost of equal shard rows.
    """
    import jax

    from repro import knn
    from repro.workloads import drive_fleet_ticks

    k = 10
    grid, batch = DEFAULT_GRID, 2048
    fleet_size, n_ticks, fleet_batch = 64, 8, 256
    g = road_network(grid, grid, seed=0)
    bn = build_bngraph(g)
    objects = pick_objects(g.n, 0.02, seed=0)
    sim = knn.FleetSim(g, fleet_size=fleet_size, seed=0)
    init = sim.positions.copy()
    trace = [sim.tick() for _ in range(n_ticks)]
    rng = np.random.default_rng(1)
    us = rng.integers(0, g.n, size=batch)

    def measure_queries(engine) -> float:
        # best of 3 windows: the parity floor divides two of these numbers,
        # so single-window scheduler noise would flap the acceptance check
        jax.block_until_ready(engine.query_batch(us)[0])  # compile off-clock
        best = 0.0
        for _ in range(3):
            t0 = time.perf_counter()
            served = 0
            while time.perf_counter() - t0 < 0.3:
                ids, _ = engine.query_batch(us)
                jax.block_until_ready(ids)
                served += batch
            best = max(best, served / (time.perf_counter() - t0))
        return best

    def measure_fleet(make_engine) -> float:
        # untimed warmup replay compiles the flush/repair shape buckets;
        # then best of 2 timed replays (same noise argument as above)
        drive_fleet_ticks(
            make_engine(), trace, batch=fleet_batch, rng=np.random.default_rng(2)
        )
        best = 0.0
        for _ in range(2):
            r = drive_fleet_ticks(
                make_engine(), trace, batch=fleet_batch, rng=np.random.default_rng(2)
            )
            best = max(best, n_ticks / max(r["wall_s"], 1e-9))
        return best

    qps_plain = measure_queries(knn.QueryEngine.build(bn, objects, k))
    ticks_plain = measure_fleet(lambda: knn.QueryEngine.build(bn, init, k))
    row("exp13.plain.query_batch", 1e6 * batch / qps_plain,
        f"{qps_plain:.0f}q/s;B={batch}")
    row("exp13.plain.fleet_tick", 1e6 / ticks_plain, f"{ticks_plain:.2f}ticks/s")

    counts = [c for c in (1, 2, 4, 8) if c <= len(jax.devices())]
    qps_by_d: dict[str, float] = {}
    ticks_by_d: dict[str, float] = {}
    pad_by_d: dict[str, float] = {}
    for d in counts:
        engine = knn.build_sharded_engine(bn, objects, k, shards=d)
        overhead = engine.stats()["row_padding_overhead"]
        qps = measure_queries(engine)
        ticks = measure_fleet(
            lambda d=d: knn.build_sharded_engine(bn, init, k, shards=d)
        )
        qps_by_d[str(d)] = round(qps, 1)
        ticks_by_d[str(d)] = round(ticks, 2)
        pad_by_d[str(d)] = overhead
        row(f"exp13.shard.d{d}.query_batch", 1e6 * batch / qps,
            f"{qps:.0f}q/s;x{qps / qps_plain:.2f}plain;pad+{overhead:.2%}")
        row(f"exp13.shard.d{d}.fleet_tick", 1e6 / ticks,
            f"{ticks:.2f}ticks/s;x{ticks / ticks_plain:.2f}plain;pad+{overhead:.2%}")

    meta("exp13.grid", grid)
    meta("exp13.k", k)
    meta("exp13.query_batch_size", batch)
    meta("exp13.fleet.size", fleet_size)
    meta("exp13.fleet.ticks", n_ticks)
    meta("exp13.devices", counts)
    meta("exp13.plain.queries_per_s", round(qps_plain, 1))
    meta("exp13.plain.ticks_per_s", round(ticks_plain, 2))
    meta("exp13.shard.queries_per_s", qps_by_d)
    meta("exp13.shard.ticks_per_s", ticks_by_d)
    meta("exp13.shard.row_padding_overhead", pad_by_d)
    meta("exp13.parity.queries_1shard_vs_plain",
         round(qps_by_d["1"] / max(qps_plain, 1e-9), 3))
    meta("exp13.parity.ticks_1shard_vs_plain",
         round(ticks_by_d["1"] / max(ticks_plain, 1e-9), 3))


def exp14_frontier_scaling() -> None:
    """Batched device checkIns frontier vs the per-object host pipeline.

    The ISSUE-5 acceptance experiment: grid=40, k=10, mu=0.05. For each
    staged-insert batch size in {8, 64, 512}, a fresh engine stages the
    SAME insert set and one flush applies it, through both checkIns
    pipelines (``engine.frontier = "host"``: one ``insert_affected_set``
    heap search per object fed by an (n,) kth readback; ``"device"``: the
    batched multi-source ``ops.frontier_relax`` rounds, kth device-resident)
    and both engine layouts (scalar / sharded at however many devices are
    visible, capped at 2). Construction is off-clock (``from_index``); the
    first rep per configuration is an untimed warmup that absorbs the jit
    compiles, then best-of-2 timed flushes. Reports staged inserts/s per
    cell and the device/host speedup; acceptance floor: the scalar device
    pipeline must reach >= 1.3x host at batch 512 (measured ~4.7x — the
    host loop re-explores every overlapping frontier region per object,
    the device rounds amortize them across the whole batch). Small batches
    are reported too and may legitimately sit below 1x: a handful of heap
    searches is cheaper than spinning up the relaxation rounds.
    """
    import jax

    from repro import knn

    k = 10
    grid, mu = 40, 0.05
    batch_sizes = (8, 64, 512)
    g = road_network(grid, grid, seed=0)
    objects = pick_objects(g.n, mu, seed=0)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    rng = np.random.default_rng(1)
    outside = np.setdiff1d(np.arange(g.n), objects)
    shards = min(2, len(jax.devices()))

    def make_engine(layout: str):
        if layout == "sharded":
            return knn.ShardedQueryEngine.from_index(
                idx, objects, bn=bn, shards=shards
            )
        return knn.QueryEngine.from_index(idx, objects, bn=bn)

    from repro.analysis import sanitize

    def measure(layout: str, mode: str, ins: np.ndarray):
        best, rounds, compiles, transfers = np.inf, 0, 0, {"h2d": 0, "d2h": 0}
        for rep in range(3):  # rep 0 = untimed compile warmup
            engine = make_engine(layout)
            engine.frontier = mode
            for u in ins:
                engine.stage_insert(int(u))
            if rep == 2:
                # last rep is fully warm: the counters here are the
                # steady-state residency profile of one flush (compiles is
                # asserted == the warm budget by check_schema)
                with sanitize.count_compiles() as cc, \
                        sanitize.count_transfers() as tc:
                    t0 = time.perf_counter()
                    stats = engine.flush_updates()
                    dt = time.perf_counter() - t0
                compiles = cc.count
                transfers = {"h2d": tc.h2d, "d2h": tc.d2h}
            else:
                t0 = time.perf_counter()
                stats = engine.flush_updates()
                dt = time.perf_counter() - t0
            rounds = stats["frontier_rounds"]
            if rep:
                best = min(best, dt)
        return best, rounds, compiles, transfers

    per_s: dict[str, dict[str, dict[str, float]]] = {
        lay: {m: {} for m in ("host", "device")} for lay in ("scalar", "sharded")
    }
    rounds_by_b: dict[str, int] = {}
    comp: dict[str, dict[str, dict[str, int]]] = {
        lay: {m: {} for m in ("host", "device")} for lay in ("scalar", "sharded")
    }
    trans: dict[str, dict[str, dict[str, dict[str, int]]]] = {
        lay: {m: {} for m in ("host", "device")} for lay in ("scalar", "sharded")
    }
    for b in batch_sizes:
        ins = rng.choice(outside, size=b, replace=False)
        for layout in ("scalar", "sharded"):
            t_host, _, c_host, tr_host = measure(layout, "host", ins)
            t_dev, rounds, c_dev, tr_dev = measure(layout, "device", ins)
            if layout == "scalar":  # record the floored pipeline's rounds
                rounds_by_b[str(b)] = rounds
            per_s[layout]["host"][str(b)] = round(b / t_host, 1)
            per_s[layout]["device"][str(b)] = round(b / t_dev, 1)
            comp[layout]["host"][str(b)] = c_host
            comp[layout]["device"][str(b)] = c_dev
            trans[layout]["host"][str(b)] = tr_host
            trans[layout]["device"][str(b)] = tr_dev
            row(f"exp14.frontier.{layout}.host.b{b}", t_host * 1e6,
                f"{b / t_host:.0f}ins/s;c{c_host};"
                f"h2d{tr_host['h2d']};d2h{tr_host['d2h']}")
            row(f"exp14.frontier.{layout}.device.b{b}", t_dev * 1e6,
                f"{b / t_dev:.0f}ins/s;x{t_host / t_dev:.2f}host;"
                f"rounds={rounds};c{c_dev};"
                f"h2d{tr_dev['h2d']};d2h{tr_dev['d2h']}")

    speedup_512 = (per_s["scalar"]["device"]["512"]
                   / max(per_s["scalar"]["host"]["512"], 1e-9))
    meta("exp14.grid", grid)
    meta("exp14.k", k)
    meta("exp14.mu", mu)
    meta("exp14.batch_sizes", list(batch_sizes))
    meta("exp14.sharded.shards", shards)
    meta("exp14.scalar.host.inserts_per_s", per_s["scalar"]["host"])
    meta("exp14.scalar.device.inserts_per_s", per_s["scalar"]["device"])
    meta("exp14.sharded.host.inserts_per_s", per_s["sharded"]["host"])
    meta("exp14.sharded.device.inserts_per_s", per_s["sharded"]["device"])
    meta("exp14.frontier_rounds", rounds_by_b)
    meta("exp14.device_speedup_b512", round(speedup_512, 2))
    meta("exp14.compiles", comp)
    meta("exp14.host_transfers", trans)


def exp15_mixed_rw() -> None:
    """Mixed read/write serving: query latency during vs between flushes.

    The ISSUE-6 acceptance experiment for epoch-versioned snapshot
    isolation. A scalar engine serves a steady ``query_batch`` stream while
    staged update batches flush round after round. "Between" samples time
    queries against the quiescent engine; "during" samples are issued from
    INSIDE ``flush_updates`` via the ``checkpoint_hook`` seam (the
    mid-repair-round / pre-swap / post-swap sites), i.e. while the pipeline
    holds half-built epoch e+1 tables. Queries resolve their dispatch-time
    epoch snapshot, so the during-flush path is the same gather over the
    immutable epoch-e buffers — it may pay queue contention with the repair
    work, but its p99 must stay within a small constant of the quiescent
    p99 (``check_schema --require exp15`` holds the ceiling). Every update
    round includes a ``stage_move`` so the purge + repair rounds — the
    expensive part of the flush — always run.
    """
    from repro import knn

    k = 10
    grid, mu = 32, 0.05
    batch = 256
    rounds = 8
    queries_per_round = 8
    g = road_network(grid, grid, seed=0)
    objects = pick_objects(g.n, mu, seed=0)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    eng = knn.QueryEngine.from_index(idx, objects, bn=bn)
    mset = set(int(o) for o in objects)
    us = query_vertices(g.n, batch, seed=3)

    def q_lat_us() -> float:
        t0 = time.perf_counter()
        ids, d = eng.query_batch(us)
        np.asarray(ids), np.asarray(d)  # block on the device result
        return (time.perf_counter() - t0) * 1e6

    def stage_round(seed: int) -> None:
        knn.stage_random_updates(eng, mset, rng=seed, count=12)
        u = sorted(mset)[0]
        v = next(w for w in range(eng.n) if w not in mset)
        eng.stage_move(u, v)
        mset.discard(u)
        mset.add(v)

    between: list[float] = []
    during: list[float] = []
    flush_s: list[float] = []

    def probe(e, phase) -> None:
        during.append(q_lat_us())

    # warmup: compile the query gather AND the whole flush pipeline with the
    # probe attached, so nothing compiles on the clock below
    for _ in range(3):
        q_lat_us()
    eng.checkpoint_hook = probe
    stage_round(seed=100)
    eng.flush_updates()
    eng.checkpoint_hook = None
    during.clear()

    for rnd in range(rounds):
        between.extend(q_lat_us() for _ in range(queries_per_round))
        stage_round(seed=rnd)
        eng.checkpoint_hook = probe
        t0 = time.perf_counter()
        eng.flush_updates()
        flush_s.append(time.perf_counter() - t0)
        eng.checkpoint_hook = None

    b50, b99 = (float(np.percentile(between, p)) for p in (50, 99))
    d50, d99 = (float(np.percentile(during, p)) for p in (50, 99))
    degrade = d99 / max(b99, 1e-9)
    flush_p50 = float(np.median(flush_s)) * 1e6
    row("exp15.mixed_rw.query_between", b50,
        f"p99={b99:.0f}us;n={len(between)}")
    row("exp15.mixed_rw.query_during", d50,
        f"p99={d99:.0f}us;n={len(during)};x{degrade:.2f}p99")
    row("exp15.mixed_rw.flush", flush_p50,
        f"{rounds}flushes;probes_on_clock={len(during) // rounds}")

    meta("exp15.grid", grid)
    meta("exp15.k", k)
    meta("exp15.mu", mu)
    meta("exp15.query_batch_size", batch)
    meta("exp15.rounds", rounds)
    meta("exp15.between.samples", len(between))
    meta("exp15.during.samples", len(during))
    meta("exp15.between.query_p50_us", round(b50, 1))
    meta("exp15.between.query_p99_us", round(b99, 1))
    meta("exp15.during.query_p50_us", round(d50, 1))
    meta("exp15.during.query_p99_us", round(d99, 1))
    meta("exp15.p99_degradation_x", round(degrade, 2))
    meta("exp15.flush_p50_us", round(flush_p50, 1))
    meta("exp15.engine.epoch", eng.epoch)


def exp16_hot_shard() -> None:
    """Replicated hot shard under a zipf-skewed query mix (ISSUE-8).

    grid=128, k=32, one 32768-query batch drawn zipf over shards
    (theta=4, so shard 0 absorbs ~92% of the traffic; uniform within a
    shard). A 4-shard engine serves the mix twice: unreplicated — the hot
    shard's query group pads every slot of the rectangular roundtrip to
    Bmax ~ 0.92*B, so three of four devices gather mostly pad rows — and
    with ``set_replication({0: 3})``, which splits the hot group across
    4 byte-identical replica slots (7 devices) and cuts Bmax ~4x. Results
    are asserted bit-identical before timing (replicas serve the same
    published epoch buffers). Floor (check_schema, multi-device CI leg):
    replicated >= 1.5x unreplicated queries/s at 8 visible devices
    (steady state measured ~1.6-1.8x; a fresh engine's first windows
    measure higher still because the unreplicated rectangle is the
    cache-cold path).
    """
    import jax

    from repro import knn

    k, grid, batch, theta = 32, 128, 32768, 4.0
    hot = 0
    g = road_network(grid, grid, seed=0)
    objects = pick_objects(g.n, 0.05, seed=1)
    bn = build_bngraph(g)
    shards = min(4, len(jax.devices()))
    replicas = min(3, len(jax.devices()) - shards)
    engine = knn.build_sharded_engine(bn, objects, k, shards=shards)
    rt = engine.routing

    rng = np.random.default_rng(2)
    w = (1.0 + np.arange(shards)) ** -theta
    owner = rng.choice(shards, size=batch, p=w / w.sum())
    lo = np.minimum(owner * rt.shard_rows, g.n - 1)
    hi = np.minimum((owner + 1) * rt.shard_rows, g.n)
    us = lo + rng.integers(0, hi - lo)
    hot_frac = float(np.mean(owner == hot))

    def measure() -> float:
        # best of 3 windows, compile off-clock (same shape as exp13: the
        # floor divides two of these, so one noisy window may not flap it)
        jax.block_until_ready(engine.query_batch(us)[0])
        best = 0.0
        for _ in range(3):
            t0 = time.perf_counter()
            served = 0
            while time.perf_counter() - t0 < 0.3:
                ids, _ = engine.query_batch(us)
                jax.block_until_ready(ids)
                served += batch
            best = max(best, served / (time.perf_counter() - t0))
        return best

    ids0, d0 = engine.query_batch(us)
    qps_un = measure()
    if replicas:
        engine.set_replication({hot: replicas})
    ids1, d1 = engine.query_batch(us)
    identical = bool(
        np.array_equal(np.asarray(ids0), np.asarray(ids1))
        and np.array_equal(np.asarray(d0), np.asarray(d1))
    )
    assert identical, "replicated results diverged from unreplicated"
    qps_rep = measure()
    speedup = qps_rep / max(qps_un, 1e-9)

    row("exp16.hot.unreplicated", 1e6 * batch / qps_un,
        f"{qps_un:.0f}q/s;hot={hot_frac:.2f};S={shards}")
    row("exp16.hot.replicated", 1e6 * batch / qps_rep,
        f"{qps_rep:.0f}q/s;x{speedup:.2f}unrep;R={replicas}")

    stats = engine.stats()
    meta("exp16.grid", grid)
    meta("exp16.k", k)
    meta("exp16.query_batch_size", batch)
    meta("exp16.devices", len(jax.devices()))
    meta("exp16.shards", shards)
    meta("exp16.zipf_theta", theta)
    meta("exp16.hot_shard", hot)
    meta("exp16.hot_frac", round(hot_frac, 3))
    meta("exp16.replicas", replicas)
    meta("exp16.identical_results", identical)
    meta("exp16.qps.unreplicated", round(qps_un, 1))
    meta("exp16.qps.replicated", round(qps_rep, 1))
    meta("exp16.speedup", round(speedup, 2))
    meta("exp16.engine.replica_queries", stats.get("replica_queries", 0))
    meta("exp16.engine.replica_batches", stats.get("replica_batches", 0))
    meta("exp16.engine.replica_errors", stats.get("replica_errors", 0))
    meta("exp16.engine.replica_policy", stats.get("replica_policy"))


def exp17_uneven_ranges() -> None:
    """Traffic-balanced uneven shard ranges vs equal-width (ISSUE-9).

    Same zipf-skewed query mix as exp16 (grid=128, k=32, one 32768-query
    batch, theta=4 so shard 0 of the equal-width layout absorbs ~92% of
    the traffic) — but ZERO replicas: instead of spending 3 extra devices
    on copies of the hot shard, the engine repartitions so each shard's
    vertex RANGE carries ~1/S of the traffic (``propose_starts`` over the
    per-vertex query histogram, applied by ``repartition`` = staged
    boundaries + one flush). The equal-width rectangle pads every device's
    gather to Bmax ~ 0.92*B; balanced boundaries cut Bmax to ~B/S with the
    same device count. Results are asserted bit-identical across the
    repartition (and to the scalar single-device oracle) before timing.
    Floor (check_schema, multi-device CI leg): uneven >= 1.3x equal-width
    queries/s at 8 visible devices, with ``replicas == 0``.
    """
    import jax

    from repro import knn
    from repro.core.partition import propose_starts

    k, grid, batch, theta = 32, 128, 32768, 4.0
    g = road_network(grid, grid, seed=0)
    objects = pick_objects(g.n, 0.05, seed=1)
    bn = build_bngraph(g)
    shards = min(4, len(jax.devices()))
    engine = knn.build_sharded_engine(bn, objects, k, shards=shards)
    rt = engine.routing

    # the exp16 traffic model: zipf over the EQUAL-WIDTH shard ranges,
    # uniform within a range (the skew the splitter has to undo)
    rng = np.random.default_rng(2)
    w = (1.0 + np.arange(shards)) ** -theta
    owner = rng.choice(shards, size=batch, p=w / w.sum())
    lo = np.minimum(owner * rt.shard_rows, g.n - 1)
    hi = np.minimum((owner + 1) * rt.shard_rows, g.n)
    us = lo + rng.integers(0, hi - lo)

    def balance() -> float:
        # max per-shard traffic share x shards: 1.0 = perfectly balanced,
        # S = everything on one shard
        counts = np.bincount(engine.routing.owner(us), minlength=engine.num_shards)
        return float(counts.max() / max(counts.sum(), 1) * engine.num_shards)

    def measure() -> float:
        # best of 3 windows, compile off-clock (same shape as exp16)
        jax.block_until_ready(engine.query_batch(us)[0])
        best = 0.0
        for _ in range(3):
            t0 = time.perf_counter()
            served = 0
            while time.perf_counter() - t0 < 0.3:
                ids, _ = engine.query_batch(us)
                jax.block_until_ready(ids)
                served += batch
            best = max(best, served / (time.perf_counter() - t0))
        return best

    bal_equal = balance()
    ids0, d0 = engine.query_batch(us)
    qps_equal = measure()

    starts = propose_starts(np.bincount(us, minlength=g.n), shards)
    engine.repartition(starts)
    bal_uneven = balance()

    ids1, d1 = engine.query_batch(us)
    identical = bool(
        np.array_equal(np.asarray(ids0), np.asarray(ids1))
        and np.array_equal(np.asarray(d0), np.asarray(d1))
    )
    assert identical, "repartitioned results diverged from equal-width"
    oracle = knn.QueryEngine.from_index(engine.to_index(), engine.objects, bn=bn)
    oi, od = oracle.query_batch(us)
    identical = identical and bool(
        np.array_equal(np.asarray(ids1), np.asarray(oi))
        and np.array_equal(np.asarray(d1), np.asarray(od))
    )
    assert identical, "uneven-range results diverged from the scalar oracle"
    del oracle
    qps_uneven = measure()
    speedup = qps_uneven / max(qps_equal, 1e-9)

    row("exp17.ranges.equal", 1e6 * batch / qps_equal,
        f"{qps_equal:.0f}q/s;bal={bal_equal:.2f};S={shards}")
    row("exp17.ranges.uneven", 1e6 * batch / qps_uneven,
        f"{qps_uneven:.0f}q/s;x{speedup:.2f}equal;bal={bal_uneven:.2f}")

    stats = engine.stats()
    meta("exp17.grid", grid)
    meta("exp17.k", k)
    meta("exp17.query_batch_size", batch)
    meta("exp17.devices", len(jax.devices()))
    meta("exp17.shards", shards)
    meta("exp17.zipf_theta", theta)
    meta("exp17.replicas", 0)
    meta("exp17.boundaries", [int(s) for s in engine.routing.starts])
    meta("exp17.balance.equal", round(bal_equal, 3))
    meta("exp17.balance.uneven", round(bal_uneven, 3))
    meta("exp17.identical_results", identical)
    meta("exp17.qps.equal", round(qps_equal, 1))
    meta("exp17.qps.uneven", round(qps_uneven, 1))
    meta("exp17.speedup", round(speedup, 2))
    meta("exp17.engine.repartitions", stats.get("repartitions", 0))
    meta("exp17.engine.uneven_ranges", stats.get("uneven_ranges"))


def exp18_halo_scaling() -> None:
    """Collective halo exchange vs the routed host halo (ISSUE-10).

    grid=48, k=10, mu=0.05. For each shard count in {2, 4, 8} the pool
    allows and each staged-insert batch in {64, 512}, the SAME insert set
    flushes through the sharded engine twice: ``halo = "host"`` (cross-
    shard repair/frontier rows fetched through host readbacks + numpy set
    algebra, re-uploaded as candidates) vs ``halo = "collective"`` (the
    default: capacity-padded all_gather multicasts keep every row device-
    resident; only the index-plan uploads and one changed-mask readback
    cross the host boundary per round). Tables are asserted bit-identical
    to each other AND the scalar oracle before timing; the collective leg
    must additionally run with zero capacity-overflow fallbacks. Each rep
    rebuilds the engine from the same index (rep 0 = untimed compile
    warmup, then best-of-3). Floor (check_schema, multi-device CI leg):
    collective >= 1.2x host flush throughput at 8 shards, batch 512 —
    that cell's host leg pays per-round fetch readbacks over the largest
    halo while the collective plan traffic stays flat.
    """
    import jax

    from repro import knn

    k, grid, mu = 10, 48, 0.05
    batch_sizes = (64, 512)
    g = road_network(grid, grid, seed=0)
    objects = pick_objects(g.n, mu, seed=0)
    bn = build_bngraph(g)
    idx = knn_index_cons_plus(bn, objects, k)
    rng = np.random.default_rng(1)
    outside = np.setdiff1d(np.arange(g.n), objects)
    counts = [c for c in (2, 4, 8) if c <= len(jax.devices())]

    def flush_once(engine, ins):
        for u in ins:
            engine.stage_insert(int(u))
        t0 = time.perf_counter()
        engine.flush_updates()
        return time.perf_counter() - t0

    def measure(shards: int, halo: str, ins: np.ndarray):
        best = np.inf
        for rep in range(4):
            engine = knn.ShardedQueryEngine.from_index(
                idx, objects, bn=bn, shards=shards
            )
            engine.halo = halo
            dt = flush_once(engine, ins)
            if rep:
                best = min(best, dt)
        return best, engine  # the last engine's tables pin bit-identity

    per_s: dict[str, dict[str, dict[str, float]]] = {
        str(d): {m: {} for m in ("host", "collective")} for d in counts
    }
    rounds_by: dict[str, int] = {}
    identical = True
    for b in batch_sizes:
        ins = rng.choice(outside, size=b, replace=False)
        oracle = knn.QueryEngine.from_index(idx, objects, bn=bn)
        flush_once(oracle, ins)
        ref = oracle.to_index()
        for d in counts:
            t_host, e_host = measure(d, "host", ins)
            t_coll, e_coll = measure(d, "collective", ins)
            stats = e_coll.stats()
            assert stats["halo_fallbacks"] == 0, (
                f"collective halo overflowed at d={d} b={b}: "
                f"{stats['halo_fallbacks']} fallbacks"
            )
            rounds_by[f"d{d}.b{b}"] = stats["halo_rounds_collective"]
            for e in (e_host, e_coll):
                got = e.to_index()
                identical = identical and bool(
                    np.array_equal(ref.ids, got.ids)
                    and np.array_equal(ref.dists, got.dists)
                )
            assert identical, f"halo tables diverged at d={d} b={b}"
            per_s[str(d)]["host"][str(b)] = round(b / t_host, 1)
            per_s[str(d)]["collective"][str(b)] = round(b / t_coll, 1)
            row(f"exp18.halo.d{d}.host.b{b}", t_host * 1e6,
                f"{b / t_host:.0f}ins/s;S={d}")
            row(f"exp18.halo.d{d}.collective.b{b}", t_coll * 1e6,
                f"{b / t_coll:.0f}ins/s;x{t_host / t_coll:.2f}host;"
                f"rounds={rounds_by[f'd{d}.b{b}']}")

    dmax = counts[-1]
    speedup_512 = (per_s[str(dmax)]["collective"]["512"]
                   / max(per_s[str(dmax)]["host"]["512"], 1e-9))
    meta("exp18.grid", grid)
    meta("exp18.k", k)
    meta("exp18.mu", mu)
    meta("exp18.batch_sizes", list(batch_sizes))
    meta("exp18.devices", len(jax.devices()))
    meta("exp18.shard_counts", counts)
    meta("exp18.inserts_per_s", per_s)
    meta("exp18.collective_rounds", rounds_by)
    meta("exp18.identical_results", identical)
    meta("exp18.speedup_b512", round(speedup_512, 2))


def exp10_vertex_orders() -> None:
    k = 20
    g, objects = dataset(grid=28)  # static orders blow up fast; small grid
    for order in ("mindeg", "degree", "id"):
        t0 = time.perf_counter()
        bn = build_bngraph(g, order=order)
        knn_index_cons_plus(bn, objects, k)
        dt = time.perf_counter() - t0
        row(f"exp10.order.{order}", dt * 1e6, f"rho={bn.rho};tau={bn.tau}")


ALL = [
    exp1_query_vs_k,
    exp2_query_vs_mu,
    exp3_progressive,
    exp4_indexing_time,
    exp5_index_size,
    exp6_vary_k_build,
    exp7_scalability,
    exp8_updates,
    exp9_throughput,
    exp10_vertex_orders,
    exp11_engine_serving,
    exp12_moving_fleet,
    exp13_sharded_scaling,
    exp14_frontier_scaling,
    exp15_mixed_rw,
    exp16_hot_shard,
    exp17_uneven_ranges,
    exp18_halo_scaling,
]
